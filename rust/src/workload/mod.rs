//! Workload primitives: adapters, requests, and adapter-set generators.
//!
//! The paper's workloads are defined by (a) a registry of adapters with
//! heterogeneous ranks and (b) a stream of requests, each naming an
//! adapter and carrying prompt/output lengths. Trace synthesis lives in
//! `trace/`; this module owns the types and the registry generators.

use crate::config::ModelSpec;
use crate::util::rng::{Pcg32, PowerLaw};

/// The paper's five production rank classes (§V-E).
pub const RANK_CLASSES: [u32; 5] = [8, 16, 32, 64, 128];

pub type AdapterId = u32;
pub type ServerId = usize;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Adapter {
    pub id: AdapterId,
    pub rank: u32,
    pub size_bytes: u64,
}

/// Registry of all adapters deployed on a cluster.
#[derive(Debug, Clone, Default)]
pub struct AdapterSet {
    pub adapters: Vec<Adapter>,
}

impl AdapterSet {
    pub fn new(adapters: Vec<Adapter>) -> Self {
        for (i, a) in adapters.iter().enumerate() {
            assert_eq!(a.id as usize, i, "adapter ids must be dense 0..n");
        }
        AdapterSet { adapters }
    }

    pub fn len(&self) -> usize {
        self.adapters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adapters.is_empty()
    }

    pub fn get(&self, id: AdapterId) -> &Adapter {
        &self.adapters[id as usize]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Adapter> {
        self.adapters.iter()
    }

    pub fn unique_ranks(&self) -> Vec<u32> {
        let mut ranks: Vec<u32> =
            self.adapters.iter().map(|a| a.rank).collect();
        ranks.sort_unstable();
        ranks.dedup();
        ranks
    }

    pub fn total_bytes(&self) -> u64 {
        self.adapters.iter().map(|a| a.size_bytes).sum()
    }

    /// Uniform counts per rank class: `n_total` adapters split evenly
    /// over `ranks` (Fig 22's "100 adapters, 20 of each rank").
    pub fn uniform_per_rank(
        n_total: usize,
        ranks: &[u32],
        model: &ModelSpec,
    ) -> AdapterSet {
        let per = n_total / ranks.len();
        let mut extra = n_total % ranks.len();
        let mut adapters = Vec::with_capacity(n_total);
        for &rank in ranks {
            let mut count = per;
            if extra > 0 {
                count += 1;
                extra -= 1;
            }
            for _ in 0..count {
                let id = adapters.len() as AdapterId;
                adapters.push(Adapter {
                    id,
                    rank,
                    size_bytes: model.adapter_bytes(rank),
                });
            }
        }
        AdapterSet::new(adapters)
    }

    /// Power-law adapter *counts within each rank class* (the paper's
    /// production-trace annotation: α=1 over adapter counts, §V-E),
    /// totalling `n_total` across the five classes.
    pub fn power_law_counts(
        n_total: usize,
        ranks: &[u32],
        alpha: f64,
        model: &ModelSpec,
    ) -> AdapterSet {
        assert!(!ranks.is_empty() && n_total >= ranks.len());
        // weight class k by (k+1)^-alpha, give each class >= 1 adapter
        let weights: Vec<f64> = (0..ranks.len())
            .map(|k| ((k + 1) as f64).powf(-alpha))
            .collect();
        let total_w: f64 = weights.iter().sum();
        let mut counts: Vec<usize> = weights
            .iter()
            .map(|w| ((w / total_w) * n_total as f64).round() as usize)
            .map(|c| c.max(1))
            .collect();
        // fix rounding drift
        loop {
            let sum: usize = counts.iter().sum();
            if sum == n_total {
                break;
            }
            if sum > n_total {
                let i = counts
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &c)| c)
                    .unwrap()
                    .0;
                counts[i] -= 1;
            } else {
                counts[0] += 1;
            }
        }
        let mut adapters = Vec::with_capacity(n_total);
        for (k, &rank) in ranks.iter().enumerate() {
            for _ in 0..counts[k] {
                let id = adapters.len() as AdapterId;
                adapters.push(Adapter {
                    id,
                    rank,
                    size_bytes: model.adapter_bytes(rank),
                });
            }
        }
        AdapterSet::new(adapters)
    }
}

/// One inference request, as carried by the traces (§V-E: request_id,
/// adapter, prompt_length, output_length, timestamp).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    pub adapter: AdapterId,
    pub prompt_len: u32,
    pub output_len: u32,
    /// Arrival time in seconds from trace start.
    pub arrival: f64,
}

impl Request {
    pub fn total_tokens(&self) -> u64 {
        self.prompt_len as u64 + self.output_len as u64
    }
}

/// Popularity model over adapters: maps a random draw to an adapter id.
#[derive(Debug, Clone)]
pub enum Popularity {
    /// All adapters equally likely.
    Uniform,
    /// Power law over adapter index (idx 0 most popular).
    PowerLaw(PowerLaw),
    /// Explicit weights per adapter (e.g. measured shares).
    Weighted(Vec<f64>),
}

impl Popularity {
    pub fn sample(&self, n: usize, rng: &mut Pcg32) -> AdapterId {
        match self {
            Popularity::Uniform => rng.below(n as u64) as AdapterId,
            Popularity::PowerLaw(pl) => {
                debug_assert_eq!(pl.len(), n);
                pl.sample(rng) as AdapterId
            }
            Popularity::Weighted(w) => {
                debug_assert_eq!(w.len(), n);
                rng.weighted_index(w) as AdapterId
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    const M: ModelSpec = ModelSpec::LLAMA_7B;

    #[test]
    fn uniform_per_rank_counts() {
        let s = AdapterSet::uniform_per_rank(100, &RANK_CLASSES, &M);
        assert_eq!(s.len(), 100);
        for &r in &RANK_CLASSES {
            let c = s.iter().filter(|a| a.rank == r).count();
            assert_eq!(c, 20, "rank {r}");
        }
        // uneven split distributes the remainder
        let s = AdapterSet::uniform_per_rank(7, &RANK_CLASSES, &M);
        assert_eq!(s.len(), 7);
        assert_eq!(s.unique_ranks(), RANK_CLASSES.to_vec());
    }

    #[test]
    fn power_law_counts_sum_and_skew() {
        for alpha in [1.0 / 3.0, 1.0, 3.0] {
            let s =
                AdapterSet::power_law_counts(50, &RANK_CLASSES, alpha, &M);
            assert_eq!(s.len(), 50, "alpha={alpha}");
            let c8 = s.iter().filter(|a| a.rank == 8).count();
            let c128 = s.iter().filter(|a| a.rank == 128).count();
            assert!(c8 >= c128, "alpha={alpha} c8={c8} c128={c128}");
            assert!(c128 >= 1);
        }
        // higher alpha concentrates more adapters in the first class
        let lo = AdapterSet::power_law_counts(200, &RANK_CLASSES, 1.0 / 3.0, &M);
        let hi = AdapterSet::power_law_counts(200, &RANK_CLASSES, 3.0, &M);
        let count8 = |s: &AdapterSet| s.iter().filter(|a| a.rank == 8).count();
        assert!(count8(&hi) > count8(&lo));
    }

    #[test]
    fn ids_dense_and_sizes_set() {
        let s = AdapterSet::uniform_per_rank(10, &[8, 128], &M);
        for (i, a) in s.iter().enumerate() {
            assert_eq!(a.id as usize, i);
            assert_eq!(a.size_bytes, M.adapter_bytes(a.rank));
        }
        assert!(s.total_bytes() > 0);
    }

    #[test]
    #[should_panic(expected = "dense")]
    fn non_dense_ids_rejected() {
        AdapterSet::new(vec![Adapter {
            id: 3,
            rank: 8,
            size_bytes: 1,
        }]);
    }

    #[test]
    fn popularity_sampling() {
        let mut rng = Pcg32::new(1);
        let u = Popularity::Uniform;
        for _ in 0..100 {
            assert!(u.sample(5, &mut rng) < 5);
        }
        let w = Popularity::Weighted(vec![0.0, 1.0, 0.0]);
        for _ in 0..50 {
            assert_eq!(w.sample(3, &mut rng), 1);
        }
        let pl = Popularity::PowerLaw(PowerLaw::new(4, 2.0));
        let mut zero = 0;
        for _ in 0..1000 {
            if pl.sample(4, &mut rng) == 0 {
                zero += 1;
            }
        }
        assert!(zero > 500);
    }

    #[test]
    fn request_tokens() {
        let r = Request {
            id: 0,
            adapter: 1,
            prompt_len: 512,
            output_len: 128,
            arrival: 0.0,
        };
        assert_eq!(r.total_tokens(), 640);
    }
}
