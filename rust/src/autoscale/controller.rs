//! The scale controller: fleet signals in, scaling decisions out.
//!
//! Pure decision logic — it never touches servers, pools, or queues,
//! so the DES loop, the real cluster, and the benches can all drive
//! it. Topology mechanics (provisioning delay, drain-and-migrate) are
//! the caller's job.

use crate::config::AutoscaleConfig;
use crate::workload::ServerId;

/// One decision window's worth of fleet signals, as gathered by the
/// simulation loop between autoscaler ticks.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScaleSignals {
    /// Mean busy fraction across active servers over the window
    /// (can exceed 1.0 slightly: iteration time is booked at start).
    pub busy_frac: f64,
    /// Fraction of the window's completions whose TTFT broke the SLO.
    pub violation_rate: f64,
    /// Requests queued/waiting/decoding across the active fleet.
    /// Vetoes scale-down: a momentarily cool fleet with a real
    /// backlog must not shrink.
    pub queue_depth: usize,
    /// Cluster-wide projected tokens/sec from the demand tracker.
    /// Sizes scale-ups predictively against the fleet's operating
    /// points (see `server_tps_capacity`).
    pub projected_tps: f64,
    /// Tokens/sec one server sustains on the workload's rank mix (the
    /// DES engine supplies the token-share-weighted harmonic mean of
    /// the per-class operating points — an unweighted mean would
    /// mis-size scale-ups on skewed mixes). With both this and
    /// `projected_tps` known, a hot fleet is sized to carry the
    /// *projected* demand — not just extrapolated from the current
    /// busy fraction. 0 (unknown) falls back to busy-fraction-only
    /// sizing.
    pub server_tps_capacity: f64,
}

/// What the controller wants done to the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Provision `k` more servers.
    Up(usize),
    /// Drain-and-retire this server.
    Down(ServerId),
}

/// SLO-aware scale controller with hysteresis.
///
/// * **Up** when the fleet is hot (`busy_frac > scale_up_util`) or the
///   SLO is already bleeding (`violation_rate > violation_rate_up`).
///   The step size aims the fleet at the midpoint of the up/down
///   thresholds so one decision is usually enough.
/// * **Down** only after two consecutive calm windows
///   (`busy_frac < scale_down_util`, zero violations, no backlog, and
///   nothing still provisioning) — the victim is the active server
///   with the least outstanding work, which drains fastest.
/// * A `cooldown` gates *all* actions, and capacity that is already
///   provisioning counts against further scale-ups, so a cold-starting
///   server is never ordered twice.
#[derive(Debug, Clone)]
pub struct ScaleController {
    cfg: AutoscaleConfig,
    last_scale: f64,
    calm_windows: u32,
}

impl ScaleController {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        ScaleController {
            cfg,
            last_scale: f64::NEG_INFINITY,
            calm_windows: 0,
        }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Evaluate one decision window. `active` lists the routable
    /// servers with their outstanding-work estimates (seconds);
    /// `provisioning` counts servers already cold-starting — capacity
    /// that is on the way and must not be ordered twice (with a long
    /// `provision_delay` the cooldown alone can expire before the
    /// first batch joins).
    pub fn decide(
        &mut self,
        now: f64,
        sig: &ScaleSignals,
        active: &[(ServerId, f64)],
        provisioning: usize,
    ) -> ScaleDecision {
        let n = active.len();
        if n == 0 || now - self.last_scale < self.cfg.cooldown {
            return ScaleDecision::Hold;
        }
        let hot = sig.busy_frac > self.cfg.scale_up_util
            || sig.violation_rate > self.cfg.violation_rate_up;
        if hot {
            self.calm_windows = 0;
            let inbound = n + provisioning;
            if inbound >= self.cfg.max_servers {
                return ScaleDecision::Hold;
            }
            // aim the post-scale fleet at the threshold midpoint,
            // counting capacity that is already provisioning
            let target =
                0.5 * (self.cfg.scale_up_util + self.cfg.scale_down_util);
            let mut desired = (n as f64
                * sig.busy_frac.max(self.cfg.scale_up_util)
                / target.max(1e-9))
            .ceil() as usize;
            // predictive sizing: when the demand tracker projects a
            // ramp, size the fleet so projected tokens/sec land at the
            // same target utilization of the per-server operating
            // point — the reactive estimate only sees load already
            // burning GPU time.
            if sig.server_tps_capacity > 0.0 && sig.projected_tps > 0.0 {
                let predictive = (sig.projected_tps
                    / (target.max(1e-9) * sig.server_tps_capacity))
                    .ceil() as usize;
                desired = desired.max(predictive);
            }
            if desired <= inbound {
                return ScaleDecision::Hold; // enough already inbound
            }
            let k = (desired - inbound)
                .clamp(1, self.cfg.max_servers - inbound);
            self.last_scale = now;
            return ScaleDecision::Up(k);
        }
        let calm = sig.busy_frac < self.cfg.scale_down_util
            && sig.violation_rate <= 0.0
            // backlog veto: ≲1 in-flight request per server
            && sig.queue_depth <= n;
        if calm && provisioning == 0 && n > self.cfg.min_servers {
            self.calm_windows += 1;
            if self.calm_windows >= 2 {
                self.calm_windows = 0;
                self.last_scale = now;
                let victim = active
                    .iter()
                    .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .map(|&(s, _)| s)
                    .unwrap();
                return ScaleDecision::Down(victim);
            }
            return ScaleDecision::Hold;
        }
        self.calm_windows = 0;
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_servers: 1,
            max_servers: 8,
            decision_period: 10.0,
            scale_up_util: 0.8,
            scale_down_util: 0.3,
            violation_rate_up: 0.05,
            cooldown: 30.0,
            provision_delay: 15.0,
        }
    }

    fn fleet(n: usize) -> Vec<(ServerId, f64)> {
        (0..n).map(|s| (s, s as f64)).collect()
    }

    fn sig(busy: f64, viol: f64) -> ScaleSignals {
        ScaleSignals {
            busy_frac: busy,
            violation_rate: viol,
            ..Default::default()
        }
    }

    #[test]
    fn scales_up_on_hot_utilization() {
        let mut c = ScaleController::new(cfg());
        match c.decide(100.0, &sig(0.95, 0.0), &fleet(2), 0) {
            ScaleDecision::Up(k) => assert!(k >= 1, "k={k}"),
            other => panic!("expected Up, got {other:?}"),
        }
    }

    #[test]
    fn scales_up_on_violations_even_when_cool() {
        let mut c = ScaleController::new(cfg());
        // queueing can violate the SLO while busy_frac looks moderate
        assert!(matches!(
            c.decide(100.0, &sig(0.5, 0.2), &fleet(2), 0),
            ScaleDecision::Up(_)
        ));
    }

    #[test]
    fn up_step_sized_by_overload() {
        let mut c = ScaleController::new(cfg());
        // 4 servers at 1.4 busy vs target 0.55 => desired ~11, capped 8
        match c.decide(100.0, &sig(1.4, 0.0), &fleet(4), 0) {
            ScaleDecision::Up(k) => assert_eq!(k, 4),
            other => panic!("{other:?}"),
        }
    }

    /// Predictive step sizing on a demand ramp: with the per-server
    /// operating point known, the scale-up step tracks the *projected*
    /// tokens/sec instead of only extrapolating busy fraction.
    #[test]
    fn predictive_sizing_follows_demand_ramp() {
        let mut c = ScaleController::new(cfg());
        let ramp = |tps: f64| ScaleSignals {
            busy_frac: 0.85, // just hot: reactive sizing alone adds 2
            projected_tps: tps,
            server_tps_capacity: 1000.0,
            ..Default::default()
        };
        // target util = (0.8 + 0.3) / 2 = 0.55
        // reactive: ceil(2 * 0.85 / 0.55) = 4 => k = 2
        match c.decide(100.0, &ramp(1000.0), &fleet(2), 0) {
            ScaleDecision::Up(k) => assert_eq!(k, 2),
            other => panic!("{other:?}"),
        }
        // projected 3300 tps / (0.55 * 1000) => 6 servers => k = 4
        match c.decide(200.0, &ramp(3300.0), &fleet(2), 0) {
            ScaleDecision::Up(k) => assert_eq!(k, 4),
            other => panic!("{other:?}"),
        }
        // projected 6000 tps => 11 desired, capped at max_servers 8
        match c.decide(300.0, &ramp(6000.0), &fleet(2), 0) {
            ScaleDecision::Up(k) => assert_eq!(k, 6),
            other => panic!("{other:?}"),
        }
        // unknown capacity: falls back to busy-fraction-only sizing
        let mut blind = ramp(6000.0);
        blind.server_tps_capacity = 0.0;
        let mut c2 = ScaleController::new(cfg());
        match c2.decide(100.0, &blind, &fleet(2), 0) {
            ScaleDecision::Up(k) => assert_eq!(k, 2),
            other => panic!("{other:?}"),
        }
        // predictive demand already covered by inbound capacity: hold
        let mut c3 = ScaleController::new(cfg());
        assert_eq!(
            c3.decide(100.0, &ramp(1000.0), &fleet(2), 2),
            ScaleDecision::Hold
        );
    }

    #[test]
    fn cooldown_gates_consecutive_actions() {
        let mut c = ScaleController::new(cfg());
        assert!(matches!(
            c.decide(100.0, &sig(0.95, 0.0), &fleet(2), 0),
            ScaleDecision::Up(_)
        ));
        assert_eq!(
            c.decide(110.0, &sig(0.95, 0.0), &fleet(2), 0),
            ScaleDecision::Hold
        );
        assert!(matches!(
            c.decide(140.0, &sig(0.95, 0.0), &fleet(2), 0),
            ScaleDecision::Up(_)
        ));
    }

    #[test]
    fn respects_max_servers() {
        let mut c = ScaleController::new(cfg());
        assert_eq!(
            c.decide(100.0, &sig(0.99, 0.5), &fleet(8), 0),
            ScaleDecision::Hold
        );
        // inbound provisioning counts against the cap too
        assert_eq!(
            c.decide(200.0, &sig(0.99, 0.5), &fleet(5), 3),
            ScaleDecision::Hold
        );
    }

    #[test]
    fn provisioning_capacity_not_ordered_twice() {
        let mut c = ScaleController::new(cfg());
        // 2 active at 0.95 busy => desired 4; with 2 already
        // provisioning the order book is full, even past the cooldown
        assert_eq!(
            c.decide(100.0, &sig(0.95, 0.0), &fleet(2), 2),
            ScaleDecision::Hold
        );
        // desired 4 with only 1 inbound => top up the difference
        assert_eq!(
            c.decide(200.0, &sig(0.95, 0.0), &fleet(2), 1),
            ScaleDecision::Up(1)
        );
    }

    #[test]
    fn backlog_vetoes_scale_down() {
        let mut c = ScaleController::new(cfg());
        let mut calm = sig(0.1, 0.0);
        calm.queue_depth = 50; // deep backlog, momentarily cool fleet
        assert_eq!(
            c.decide(100.0, &calm, &fleet(3), 0),
            ScaleDecision::Hold
        );
        assert_eq!(
            c.decide(110.0, &calm, &fleet(3), 0),
            ScaleDecision::Hold
        );
        // backlog cleared: the two-calm-window streak starts fresh
        assert_eq!(
            c.decide(120.0, &sig(0.1, 0.0), &fleet(3), 0),
            ScaleDecision::Hold
        );
        assert!(matches!(
            c.decide(130.0, &sig(0.1, 0.0), &fleet(3), 0),
            ScaleDecision::Down(_)
        ));
    }

    #[test]
    fn scale_down_needs_two_calm_windows_and_picks_idlest() {
        let mut c = ScaleController::new(cfg());
        let active = vec![(3usize, 5.0), (5usize, 0.5), (7usize, 9.0)];
        assert_eq!(
            c.decide(100.0, &sig(0.1, 0.0), &active, 0),
            ScaleDecision::Hold
        );
        assert_eq!(
            c.decide(110.0, &sig(0.1, 0.0), &active, 0),
            ScaleDecision::Down(5)
        );
    }

    #[test]
    fn violations_reset_calm_streak() {
        let mut c = ScaleController::new(cfg());
        assert_eq!(
            c.decide(100.0, &sig(0.1, 0.0), &fleet(3), 0),
            ScaleDecision::Hold
        );
        // a violated window breaks the streak (moderate busy => Hold)
        assert_eq!(
            c.decide(110.0, &sig(0.5, 0.0), &fleet(3), 0),
            ScaleDecision::Hold
        );
        assert_eq!(
            c.decide(120.0, &sig(0.1, 0.0), &fleet(3), 0),
            ScaleDecision::Hold
        );
        assert!(matches!(
            c.decide(130.0, &sig(0.1, 0.0), &fleet(3), 0),
            ScaleDecision::Down(_)
        ));
    }

    #[test]
    fn never_shrinks_below_min() {
        let mut c = ScaleController::new(cfg());
        for t in 0..10 {
            assert_eq!(
                c.decide(100.0 * t as f64, &sig(0.0, 0.0), &fleet(1), 0),
                ScaleDecision::Hold
            );
        }
    }
}
