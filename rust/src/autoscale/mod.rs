//! Elastic capacity subsystem: SLO-aware autoscaling + minimum-GPU
//! capacity planning.
//!
//! The paper's headline claim is that rank-aware placement meets SLOs
//! with **up to 50% fewer GPUs**; this module turns the fixed-fleet
//! reproduction into an elastic, SLO-driven system with two parts:
//!
//! * [`controller`] — the **scale controller**: every
//!   `AutoscaleConfig::decision_period` seconds the DES loop feeds it
//!   fleet signals (busy fraction, TTFT-SLO violation rate, queue
//!   depth, projected demand) and it answers `ScaleUp(k)` /
//!   `ScaleDown(victim)` / `Hold`, with hysteresis and a cooldown so
//!   the fleet doesn't flap. Scale-downs trigger the
//!   **drain-and-migrate protocol** in `sim::cluster`: the victim
//!   leaves the routing table immediately, its queued/waiting work is
//!   re-routed through the swapped table, its adapters are re-placed
//!   onto the survivors, and any *last-copy* adapters are
//!   RDMA-migrated before the server retires — the pool coverage
//!   invariant holds at every step of a shrink.
//!
//! * [`planner`] — the **capacity planner**: bisects the minimum
//!   server count whose fixed-fleet simulation meets a configurable
//!   TTFT/E2E SLO percentile, per `SystemKind` — reproducing the
//!   ≤50%-fewer-GPUs comparison as `min_fleet(LORASERVE)` vs
//!   `min_fleet(baseline)`.
//!
//! Fleet accounting (GPU-seconds, scale-event counters, fleet-size
//! timeline) lives in [`crate::metrics::FleetMetrics`]; the CLI entry
//! point is the `autoscale` subcommand.

pub mod controller;
pub mod planner;

pub use controller::{ScaleController, ScaleDecision, ScaleSignals};
pub use planner::{plan_min_fleet, PlanResult, SloMetric, SloSpec};
