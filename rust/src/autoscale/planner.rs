//! The capacity planner: smallest fleet meeting an SLO percentile.
//!
//! `min_fleet(system)` is the paper's "GPUs needed" metric behind the
//! "up to 50% fewer GPUs" claim, generalized to a configurable
//! TTFT/E2E percentile. Feasibility is assumed monotone in the server
//! count (more servers never hurt a system's tail latency at fixed
//! load — true for every placer here since each runs strictly more
//! capacity), which lets a bisection replace the old linear scan:
//! O(log n) simulations instead of O(n).

use crate::config::ClusterConfig;
use crate::sim::{self, SimConfig, SimReport, SystemKind};
use crate::trace::Trace;

/// Which latency the SLO constrains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloMetric {
    /// Time to first token (queueing + fetch + prefill).
    Ttft,
    /// End-to-end request latency (arrival → last token).
    E2e,
}

/// A latency objective: `percentile` of `metric` must be ≤ `threshold`
/// seconds (and ≥99% of offered requests must complete).
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    pub metric: SloMetric,
    pub percentile: f64,
    pub threshold: f64,
}

impl SloSpec {
    /// The paper's default SLA shape: P95 TTFT ≤ `threshold`.
    pub fn ttft_p95(threshold: f64) -> Self {
        SloSpec {
            metric: SloMetric::Ttft,
            percentile: 95.0,
            threshold,
        }
    }

    /// The constrained latency observed in a finished run.
    pub fn observed(&self, rep: &mut SimReport) -> f64 {
        match self.metric {
            SloMetric::Ttft => rep.ttft.percentile(self.percentile),
            SloMetric::E2e => rep.e2e.percentile(self.percentile),
        }
    }

    /// The paper's SLA check at this spec's metric/percentile.
    pub fn met_by(&self, rep: &mut SimReport) -> bool {
        let obs = self.observed(rep);
        rep.completed > 0
            && rep.completion_rate() >= 0.99
            && obs <= self.threshold
    }
}

/// Outcome of one capacity search.
#[derive(Debug, Clone)]
pub struct PlanResult {
    pub system: SystemKind,
    /// Smallest feasible fleet, or None if even `max_servers` misses.
    pub min_servers: Option<usize>,
    /// Every (n_servers, observed latency, met) the search simulated.
    pub probes: Vec<(usize, f64, bool)>,
}

impl PlanResult {
    /// GPUs of the minimum fleet (`servers × tensor-parallel degree`).
    pub fn gpus(&self, tp: usize) -> Option<usize> {
        self.min_servers.map(|n| n * tp)
    }

    /// Observed latency at the chosen minimum fleet.
    pub fn observed_at_min(&self) -> Option<f64> {
        let n = self.min_servers?;
        self.probes.iter().find(|p| p.0 == n).map(|p| p.1)
    }
}

fn probe(
    trace: &Trace,
    base: &ClusterConfig,
    system: SystemKind,
    n_servers: usize,
    slo: &SloSpec,
) -> (bool, f64) {
    let mut cluster = base.clone();
    cluster.n_servers = n_servers;
    // steady-state measurement, as in the figure harnesses
    let warmup =
        (2.0 * cluster.rebalance_period).min(trace.duration() / 3.0);
    let mut rep = sim::run(
        trace,
        &SimConfig::new(cluster, system).with_warmup(warmup),
    );
    let ok = slo.met_by(&mut rep);
    (ok, slo.observed(&mut rep))
}

/// Bisect the minimum server count (1..=`max_servers`) whose
/// fixed-fleet simulation of `trace` meets `slo`. Deterministic per
/// (trace, config, system).
pub fn plan_min_fleet(
    trace: &Trace,
    base: &ClusterConfig,
    system: SystemKind,
    slo: &SloSpec,
    max_servers: usize,
) -> PlanResult {
    assert!(max_servers >= 1);
    let mut probes = Vec::new();
    let (ok_max, obs_max) = probe(trace, base, system, max_servers, slo);
    probes.push((max_servers, obs_max, ok_max));
    if !ok_max {
        return PlanResult {
            system,
            min_servers: None,
            probes,
        };
    }
    if max_servers == 1 {
        return PlanResult {
            system,
            min_servers: Some(1),
            probes,
        };
    }
    let (ok_one, obs_one) = probe(trace, base, system, 1, slo);
    probes.push((1, obs_one, ok_one));
    if ok_one {
        return PlanResult {
            system,
            min_servers: Some(1),
            probes,
        };
    }
    // invariant: lo infeasible, hi feasible
    let (mut lo, mut hi) = (1usize, max_servers);
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        let (ok, obs) = probe(trace, base, system, mid, slo);
        probes.push((mid, obs, ok));
        if ok {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    PlanResult {
        system,
        min_servers: Some(hi),
        probes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::azure::{self, AzureConfig};
    use crate::trace::LengthModel;

    fn trace(rps: f64) -> Trace {
        azure::generate(&AzureConfig {
            rps: 8.0,
            duration: 90.0,
            seed: 1,
            lengths: LengthModel::fixed(512, 128),
            ..Default::default()
        })
        .scale_to_rps(rps)
    }

    #[test]
    fn bisection_finds_boundary_fleet() {
        let base = ClusterConfig::default();
        let slo = SloSpec::ttft_p95(base.slo.ttft_p95);
        let plan = plan_min_fleet(
            &trace(8.0),
            &base,
            SystemKind::LoraServe,
            &slo,
            8,
        );
        let n = plan.min_servers.expect("8 servers must suffice");
        assert!((1..=8).contains(&n));
        // the boundary is real: n meets, n-1 (if probed) does not
        for &(m, _, ok) in &plan.probes {
            if m < n {
                assert!(!ok, "probe {m} met but min is {n}");
            }
        }
        assert!(plan.observed_at_min().is_some());
        assert_eq!(plan.gpus(4), Some(n * 4));
        // O(log n): never more than 2 + log2(8) probes
        assert!(plan.probes.len() <= 5, "{} probes", plan.probes.len());
    }

    #[test]
    fn infeasible_load_returns_none() {
        let base = ClusterConfig::default();
        let slo = SloSpec::ttft_p95(0.001); // 1 ms: impossible
        let plan = plan_min_fleet(
            &trace(8.0),
            &base,
            SystemKind::SLoraRandom,
            &slo,
            2,
        );
        assert!(plan.min_servers.is_none());
        assert_eq!(plan.probes.len(), 1);
    }

    #[test]
    fn min_fleet_monotone_in_load() {
        let base = ClusterConfig::default();
        let slo = SloSpec::ttft_p95(base.slo.ttft_p95);
        let light = plan_min_fleet(
            &trace(2.0),
            &base,
            SystemKind::LoraServe,
            &slo,
            8,
        )
        .min_servers
        .unwrap();
        let heavy = plan_min_fleet(
            &trace(12.0),
            &base,
            SystemKind::LoraServe,
            &slo,
            8,
        )
        .min_servers
        .unwrap();
        assert!(heavy >= light, "{heavy} < {light}");
    }

    #[test]
    fn e2e_metric_uses_e2e_samples() {
        let base = ClusterConfig::default();
        let slo = SloSpec {
            metric: SloMetric::E2e,
            percentile: 50.0,
            threshold: 120.0, // generous: any working fleet passes
        };
        let plan = plan_min_fleet(
            &trace(4.0),
            &base,
            SystemKind::LoraServe,
            &slo,
            4,
        );
        assert!(plan.min_servers.is_some());
        let obs = plan.observed_at_min().unwrap();
        assert!(obs.is_finite() && obs > 0.0);
    }
}
