//! The capacity planner: smallest fleet meeting an SLO percentile.
//!
//! `min_fleet(system)` is the paper's "GPUs needed" metric behind the
//! "up to 50% fewer GPUs" claim, generalized to a configurable
//! TTFT/E2E percentile. Feasibility is assumed monotone in the server
//! count (more servers never hurt a system's tail latency at fixed
//! load — true for every placer here since each runs strictly more
//! capacity), which lets a bisection replace the old linear scan:
//! O(log n) simulations instead of O(n).
//!
//! Because the assumption can break (placement randomness, borderline
//! timeout cascades), a **boundary guard** checks both edges of the
//! reported minimum after the bisection: `min−1` missing the SLO is
//! certified from the search's own probe log (the bisection always
//! probed it; runs are deterministic, so re-simulating would repeat
//! the same answer), and `min+1` — which the bisection never visits —
//! is probed fresh and must meet the SLO. A violation is reported in
//! `PlanResult::warnings` and the answer is corrected to the nearest
//! *stable* boundary (probes are cached, so the guard costs at most
//! one extra simulation in the monotone case).

use crate::config::ClusterConfig;
use crate::sim::{self, SimConfig, SimReport, SystemKind};
use crate::trace::Trace;

/// Which latency the SLO constrains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloMetric {
    /// Time to first token (queueing + fetch + prefill).
    Ttft,
    /// End-to-end request latency (arrival → last token).
    E2e,
}

/// A latency objective: `percentile` of `metric` must be ≤ `threshold`
/// seconds (and ≥99% of offered requests must complete).
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    pub metric: SloMetric,
    pub percentile: f64,
    pub threshold: f64,
}

impl SloSpec {
    /// The paper's default SLA shape: P95 TTFT ≤ `threshold`.
    pub fn ttft_p95(threshold: f64) -> Self {
        SloSpec {
            metric: SloMetric::Ttft,
            percentile: 95.0,
            threshold,
        }
    }

    /// The constrained latency observed in a finished run.
    pub fn observed(&self, rep: &mut SimReport) -> f64 {
        match self.metric {
            SloMetric::Ttft => rep.ttft.percentile(self.percentile),
            SloMetric::E2e => rep.e2e.percentile(self.percentile),
        }
    }

    /// The paper's SLA check at this spec's metric/percentile.
    pub fn met_by(&self, rep: &mut SimReport) -> bool {
        let obs = self.observed(rep);
        rep.completed > 0
            && rep.completion_rate() >= 0.99
            && obs <= self.threshold
    }
}

/// Outcome of one capacity search.
#[derive(Debug, Clone)]
pub struct PlanResult {
    pub system: SystemKind,
    /// Smallest feasible fleet, or None if even `max_servers` misses.
    pub min_servers: Option<usize>,
    /// Every (n_servers, observed latency, met) the search simulated.
    pub probes: Vec<(usize, f64, bool)>,
    /// Non-empty when the boundary guard found feasibility to be
    /// non-monotone around the reported minimum (the answer has been
    /// corrected to a stable boundary).
    pub warnings: Vec<String>,
}

impl PlanResult {
    /// GPUs of the minimum fleet (`servers × tensor-parallel degree`).
    pub fn gpus(&self, tp: usize) -> Option<usize> {
        self.min_servers.map(|n| n * tp)
    }

    /// Observed latency at the chosen minimum fleet.
    pub fn observed_at_min(&self) -> Option<f64> {
        let n = self.min_servers?;
        self.probes.iter().find(|p| p.0 == n).map(|p| p.1)
    }
}

fn probe(
    trace: &Trace,
    base: &ClusterConfig,
    system: SystemKind,
    n_servers: usize,
    slo: &SloSpec,
) -> (bool, f64) {
    let mut cluster = base.clone();
    cluster.n_servers = n_servers;
    // steady-state measurement, as in the figure harnesses
    let warmup =
        (2.0 * cluster.rebalance_period).min(trace.duration() / 3.0);
    let mut rep = sim::run(
        trace,
        &SimConfig::new(cluster, system).with_warmup(warmup),
    );
    let ok = slo.met_by(&mut rep);
    (ok, slo.observed(&mut rep))
}

/// Bisection + boundary guard over an arbitrary feasibility probe.
/// Split from the simulation so the non-monotone correction logic is
/// property-testable with synthetic feasibility functions. Probes are
/// cached: no fleet size is ever simulated twice.
fn search_min_fleet(
    max_servers: usize,
    probe_fn: &mut dyn FnMut(usize) -> (bool, f64),
) -> (Option<usize>, Vec<(usize, f64, bool)>, Vec<String>) {
    assert!(max_servers >= 1);
    let mut probes: Vec<(usize, f64, bool)> = Vec::new();
    let mut probe = |n: usize,
                     probes: &mut Vec<(usize, f64, bool)>|
     -> (bool, f64) {
        if let Some(&(_, obs, ok)) = probes.iter().find(|p| p.0 == n) {
            return (ok, obs);
        }
        let (ok, obs) = probe_fn(n);
        probes.push((n, obs, ok));
        (ok, obs)
    };
    let (ok_max, _) = probe(max_servers, &mut probes);
    if !ok_max {
        return (None, probes, Vec::new());
    }
    let mut min = if max_servers == 1 {
        1
    } else if probe(1, &mut probes).0 {
        1
    } else {
        // invariant: lo infeasible, hi feasible
        let (mut lo, mut hi) = (1usize, max_servers);
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if probe(mid, &mut probes).0 {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    };
    // ---- boundary guard: verify the monotonicity assumption where it
    // actually matters, and correct the answer if it fails there.
    //
    // Below the boundary nothing new needs simulating: whenever
    // min > 1, the search itself established min−1 infeasible (the
    // `ok_one` early path or the bisection's final `lo`), and that
    // probe is in the log — `probes` certifies the lower edge. The
    // guard's added coverage is the *upper* edge: min+1 must also be
    // feasible, which the bisection never checks.
    let mut warnings = Vec::new();
    debug_assert!(
        min == 1 || probes.iter().any(|&(n, _, ok)| n == min - 1 && !ok),
        "search invariant broken: min−1 not certified infeasible"
    );
    if min < max_servers && !probe(min + 1, &mut probes).0 {
        warnings.push(format!(
            "non-monotone feasibility above the boundary: {min} meets \
             the SLO but {} does not; correcting upward to a stable \
             plateau",
            min + 1
        ));
        // walk up to the next feasible fleet whose successor is also
        // feasible (max_servers, known feasible, bounds the walk)
        let mut m = min + 1;
        loop {
            while m < max_servers && !probe(m, &mut probes).0 {
                m += 1;
            }
            if m == max_servers || probe(m + 1, &mut probes).0 {
                break;
            }
            m += 1;
        }
        min = m;
    }
    (Some(min), probes, warnings)
}

/// Bisect the minimum server count (1..=`max_servers`) whose
/// fixed-fleet simulation of `trace` meets `slo`, then guard the
/// boundary (certify `min−1` from the probe log, probe `min+1`,
/// warn-and-correct if feasibility is non-monotone there).
/// Deterministic per (trace, config, system).
pub fn plan_min_fleet(
    trace: &Trace,
    base: &ClusterConfig,
    system: SystemKind,
    slo: &SloSpec,
    max_servers: usize,
) -> PlanResult {
    let mut probe_fn =
        |n: usize| -> (bool, f64) { probe(trace, base, system, n, slo) };
    let (min_servers, probes, warnings) =
        search_min_fleet(max_servers, &mut probe_fn);
    for w in &warnings {
        eprintln!("[planner:{}] {w}", system.label());
    }
    PlanResult {
        system,
        min_servers,
        probes,
        warnings,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::azure::{self, AzureConfig};
    use crate::trace::LengthModel;

    fn trace(rps: f64) -> Trace {
        azure::generate(&AzureConfig {
            rps: 8.0,
            duration: 90.0,
            seed: 1,
            lengths: LengthModel::fixed(512, 128),
            ..Default::default()
        })
        .scale_to_rps(rps)
    }

    #[test]
    fn bisection_finds_boundary_fleet() {
        let base = ClusterConfig::default();
        let slo = SloSpec::ttft_p95(base.slo.ttft_p95);
        let plan = plan_min_fleet(
            &trace(8.0),
            &base,
            SystemKind::LoraServe,
            &slo,
            8,
        );
        let n = plan.min_servers.expect("8 servers must suffice");
        assert!((1..=8).contains(&n));
        // the boundary is real: n meets, n-1 (if probed) does not
        for &(m, _, ok) in &plan.probes {
            if m < n {
                assert!(!ok, "probe {m} met but min is {n}");
            }
        }
        assert!(plan.observed_at_min().is_some());
        assert_eq!(plan.gpus(4), Some(n * 4));
        // O(log n): 2 + log2(8) bisection probes, plus at most one
        // extra for the boundary guard's min+1 check
        assert!(plan.probes.len() <= 6, "{} probes", plan.probes.len());
        // monotone regime: the guard stays silent and certifies the
        // boundary (min+1 feasible whenever it was probed)
        assert!(plan.warnings.is_empty(), "{:?}", plan.warnings);
        if n < 8 {
            let above = plan
                .probes
                .iter()
                .find(|p| p.0 == n + 1)
                .expect("guard must probe min+1");
            assert!(above.2, "min+1 infeasible yet no warning");
        }
    }

    /// Drive the search with synthetic feasibility functions to prove
    /// the guard's warn-and-correct behavior in regimes the (monotone)
    /// simulator cannot produce.
    #[test]
    fn boundary_guard_corrects_non_monotone_feasibility() {
        use super::search_min_fleet;
        let run = |feasible: &[usize], max: usize| {
            let set: Vec<usize> = feasible.to_vec();
            let mut f = |n: usize| -> (bool, f64) {
                (set.contains(&n), n as f64)
            };
            search_min_fleet(max, &mut f)
        };
        // monotone: min found, no warnings
        let (min, probes, warns) = run(&[4, 5, 6, 7, 8], 8);
        assert_eq!(min, Some(4));
        assert!(warns.is_empty());
        assert!(probes.iter().filter(|p| p.0 == 4).count() == 1);
        // hole just above the bisection answer: 4 feasible, 5 not —
        // corrected upward to the stable plateau at 6
        let (min, _, warns) = run(&[4, 6, 7, 8], 8);
        assert_eq!(min, Some(6), "must land on a stable boundary");
        assert_eq!(warns.len(), 1);
        assert!(warns[0].contains("above the boundary"));
        // islands: every other size feasible — still terminates, still
        // stable (7 and 8 both feasible)
        let (min, _, warns) = run(&[2, 4, 8], 8);
        assert_eq!(min, Some(8));
        assert!(!warns.is_empty());
        // nothing feasible at max: no answer, no guard
        let (min, probes, warns) = run(&[2], 8);
        assert_eq!(min, None);
        assert_eq!(probes.len(), 1);
        assert!(warns.is_empty());
        // max_servers == 1 degenerate case
        let (min, _, warns) = run(&[1], 1);
        assert_eq!(min, Some(1));
        assert!(warns.is_empty());
    }

    #[test]
    fn boundary_guard_probe_cache_never_repeats() {
        use super::search_min_fleet;
        let mut calls: Vec<usize> = Vec::new();
        let mut f = |n: usize| -> (bool, f64) {
            calls.push(n);
            (n >= 3, 0.0)
        };
        let (min, probes, warns) = search_min_fleet(8, &mut f);
        assert_eq!(min, Some(3));
        assert!(warns.is_empty());
        let mut sorted = calls.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(
            sorted.len(),
            calls.len(),
            "probe cache failed: {calls:?}"
        );
        assert_eq!(probes.len(), calls.len());
    }

    /// The planner inherits every scheduler knob from the cluster
    /// config, so min-fleet tables reflect decode-aware systems: a
    /// rank-partitioned plan searches and lands on a sane boundary
    /// just like the unified baseline.
    #[test]
    fn planner_respects_decode_policy() {
        use crate::config::DecodePolicyKind;
        let base = ClusterConfig {
            decode_policy: DecodePolicyKind::RankPartitioned,
            ..Default::default()
        };
        let slo = SloSpec::ttft_p95(base.slo.ttft_p95);
        let plan = plan_min_fleet(
            &trace(8.0),
            &base,
            SystemKind::LoraServe,
            &slo,
            8,
        );
        let n = plan.min_servers.expect("8 servers must suffice");
        assert!((1..=8).contains(&n));
        assert!(plan.observed_at_min().is_some());
    }

    #[test]
    fn infeasible_load_returns_none() {
        let base = ClusterConfig::default();
        let slo = SloSpec::ttft_p95(0.001); // 1 ms: impossible
        let plan = plan_min_fleet(
            &trace(8.0),
            &base,
            SystemKind::SLoraRandom,
            &slo,
            2,
        );
        assert!(plan.min_servers.is_none());
        assert_eq!(plan.probes.len(), 1);
    }

    #[test]
    fn min_fleet_monotone_in_load() {
        let base = ClusterConfig::default();
        let slo = SloSpec::ttft_p95(base.slo.ttft_p95);
        let light = plan_min_fleet(
            &trace(2.0),
            &base,
            SystemKind::LoraServe,
            &slo,
            8,
        )
        .min_servers
        .unwrap();
        let heavy = plan_min_fleet(
            &trace(12.0),
            &base,
            SystemKind::LoraServe,
            &slo,
            8,
        )
        .min_servers
        .unwrap();
        assert!(heavy >= light, "{heavy} < {light}");
    }

    #[test]
    fn e2e_metric_uses_e2e_samples() {
        let base = ClusterConfig::default();
        let slo = SloSpec {
            metric: SloMetric::E2e,
            percentile: 50.0,
            threshold: 120.0, // generous: any working fleet passes
        };
        let plan = plan_min_fleet(
            &trace(4.0),
            &base,
            SystemKind::LoraServe,
            &slo,
            4,
        );
        assert!(plan.min_servers.is_some());
        let obs = plan.observed_at_min().unwrap();
        assert!(obs.is_finite() && obs > 0.0);
    }
}
