//! Configuration: model/GPU specs, cluster layout, SLOs.
//!
//! `ModelSpec` carries the published Llama dimensions used by the
//! analytical cost model; `GpuSpec` the A100 parts the paper's testbed
//! used; `ClusterConfig`/`SloConfig` the experiment-level knobs. Configs
//! load from JSON files (see `examples/configs/`) with CLI overrides.

use crate::util::json::{self, Json};

/// Transformer dimensions for the cost model. LoRA is applied to the
/// q/k/v/o projections of every layer (the paper's setting, §III-A.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub params: f64,        // total parameter count
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub bytes_per_param: f64, // serving precision (fp16 = 2.0)
}

impl ModelSpec {
    pub const LLAMA_7B: ModelSpec = ModelSpec {
        name: "llama-7b",
        params: 6.74e9,
        n_layers: 32,
        d_model: 4096,
        n_heads: 32,
        d_ff: 11008,
        bytes_per_param: 2.0,
    };
    pub const LLAMA_13B: ModelSpec = ModelSpec {
        name: "llama-13b",
        params: 13.0e9,
        n_layers: 40,
        d_model: 5120,
        n_heads: 40,
        d_ff: 13824,
        bytes_per_param: 2.0,
    };
    pub const LLAMA_30B: ModelSpec = ModelSpec {
        name: "llama-30b",
        params: 32.5e9,
        n_layers: 60,
        d_model: 6656,
        n_heads: 52,
        d_ff: 17920,
        bytes_per_param: 2.0,
    };
    pub const LLAMA_70B: ModelSpec = ModelSpec {
        name: "llama-70b",
        params: 70.0e9,
        n_layers: 80,
        d_model: 8192,
        n_heads: 64,
        d_ff: 28672,
        bytes_per_param: 2.0,
    };

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name {
            "llama-7b" | "7b" => Some(Self::LLAMA_7B),
            "llama-13b" | "13b" => Some(Self::LLAMA_13B),
            "llama-30b" | "30b" => Some(Self::LLAMA_30B),
            "llama-70b" | "70b" => Some(Self::LLAMA_70B),
            _ => None,
        }
    }

    /// Weight bytes of the base model at serving precision.
    pub fn weight_bytes(&self) -> f64 {
        self.params * self.bytes_per_param
    }

    /// LoRA adapter byte size for a given rank: A[d,r] + B[r,d] per
    /// projection, 4 projections (q,k,v,o) per layer.
    pub fn adapter_bytes(&self, rank: u32) -> u64 {
        let params =
            8.0 * self.d_model as f64 * rank as f64 * self.n_layers as f64;
        (params * self.bytes_per_param) as u64
    }

    /// KV-cache bytes per token (fp16 K and V across all layers).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_layers as f64
            * self.d_model as f64
            * self.bytes_per_param
    }
}

/// GPU part used by the cost model. Numbers are vendor specs; the
/// *effective* fractions live in `costmodel::calib`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    pub peak_flops: f64,    // dense fp16/bf16 FLOP/s
    pub hbm_bw: f64,        // bytes/s
    pub hbm_bytes: f64,
    pub pcie_bw: f64,       // host<->device bytes/s
    pub nvlink_bw: f64,     // intra-node GPU<->GPU bytes/s
    pub ib_bw: f64,         // inter-node (InfiniBand HDR) bytes/s per GPU
    pub ssd_bw: f64,        // local NVMe read bytes/s
}

impl GpuSpec {
    /// A100 SXM 40GB on Standard_ND96asr_v4 (8x HDR IB @200Gb/s).
    pub const A100_40G: GpuSpec = GpuSpec {
        name: "a100-40g",
        peak_flops: 312e12,
        hbm_bw: 1.555e12,
        hbm_bytes: 40e9,
        pcie_bw: 25e9,
        nvlink_bw: 300e9,
        ib_bw: 25e9,
        ssd_bw: 2.0e9,
    };
    /// A100 PCIe 80GB on Standard_NC24ads_A100_v4.
    pub const A100_80G: GpuSpec = GpuSpec {
        name: "a100-80g",
        peak_flops: 312e12,
        hbm_bw: 1.935e12,
        hbm_bytes: 80e9,
        pcie_bw: 25e9,
        nvlink_bw: 0.0,
        ib_bw: 12.5e9,
        ssd_bw: 2.0e9,
    };
}

/// Latency SLOs (the paper uses P95 TTFT ≤ 10 s for scalability,
/// 20 s for Fig 6; requests past `timeout` count as violations and are
/// dropped by the simulated frontends). `e2e_p95` is an optional
/// end-to-end latency objective consumed by the capacity planner —
/// infinite (disabled) by default because the paper's SLA is on TTFT.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    pub ttft_p95: f64,
    pub e2e_p95: f64,
    pub timeout: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            ttft_p95: 10.0,
            e2e_p95: f64::INFINITY,
            timeout: 120.0,
        }
    }
}

/// Knobs of the SLO-aware autoscaler (`autoscale::ScaleController`).
///
/// The controller evaluates fleet signals every `decision_period`
/// seconds: it grows the fleet when mean busy fraction exceeds
/// `scale_up_util` or the window's TTFT-SLO violation rate exceeds
/// `violation_rate_up`, and shrinks (after two consecutive calm
/// windows, drain-and-migrate protocol) when busy fraction falls below
/// `scale_down_util`. `cooldown` seconds must elapse between scaling
/// actions; a new server takes `provision_delay` seconds of cold start
/// before it joins the routable fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleConfig {
    pub min_servers: usize,
    pub max_servers: usize,
    pub decision_period: f64,
    pub scale_up_util: f64,
    pub scale_down_util: f64,
    pub violation_rate_up: f64,
    pub cooldown: f64,
    pub provision_delay: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_servers: 1,
            max_servers: 16,
            decision_period: 15.0,
            scale_up_util: 0.85,
            scale_down_util: 0.35,
            violation_rate_up: 0.05,
            cooldown: 60.0,
            provision_delay: 30.0,
        }
    }
}

/// Knobs of the scheduler's SLO feedback layer (`sim::slo::SloTracker`).
///
/// When `enabled`, every simulated server carries a rolling
/// TTFT/TBT-headroom tracker that closes the loop between observed
/// latency pressure and the batch/decode policies:
///
/// * **Preemptible decode rounds** (`preempt_decode`): between the
///   sub-batch steps of a [`DecodePlan`](crate::sim::DecodePlan) round,
///   a queued prefill may preempt the remaining steps when the queue
///   head's projected TTFT headroom falls below `pressure_theta ×
///   ttft_target`; the dropped steps are re-planned after the
///   admission, so no request is ever lost.
/// * **SLO-aware rotor**: `class-subbatch` decode serves the rank class
///   with the worst rolling TBT headroom first, falling back to the
///   cyclic fairness rotor when headrooms tie.
/// * **Adaptive admission wait**: `rank-bucketed` scales its
///   bounded-wait starvation guard by the queue head's remaining TTFT
///   headroom, forcing the head class through as the target drains.
///
/// Disabled (the default), the scheduler is exactly the PR 3 open-loop
/// scheduler, bit for bit. CLI: `--slo-ttft-ms`, `--slo-tbt-ms`,
/// `--preempt-decode on|off`; JSON: `slo_ttft_ms`, `slo_tbt_ms`,
/// `preempt_decode`, `slo_pressure_theta`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloFeedbackConfig {
    /// Master switch: install the per-server tracker.
    pub enabled: bool,
    /// Scheduler-level TTFT target the tracker measures headroom
    /// against, seconds. (Distinct from `SloConfig::ttft_p95`, the
    /// evaluation SLA — the feedback target is typically much tighter.)
    pub ttft_target: f64,
    /// Per-token TBT target, seconds.
    pub tbt_target: f64,
    /// Allow queued prefills to preempt a decode round between its
    /// sub-batch steps under TTFT pressure.
    pub preempt_decode: bool,
    /// Pressure threshold: headroom below `pressure_theta ×
    /// ttft_target` counts as TTFT pressure. In [0, 1].
    pub pressure_theta: f64,
}

impl Default for SloFeedbackConfig {
    fn default() -> Self {
        SloFeedbackConfig {
            enabled: false,
            ttft_target: 10.0,
            tbt_target: 0.2,
            preempt_decode: false,
            pressure_theta: 0.5,
        }
    }
}

/// When the placement layer re-places adapters (the paper's
/// "dynamically rebalances adapters across GPUs").
///
/// * `Periodic` — the open-loop timer: a full re-place every
///   `rebalance_period` seconds (the PR 4 behavior, bit for bit).
/// * `Triggered` — drift-reactive: a [`RebalanceConfig`] trigger
///   watches the projected per-server load-imbalance ratio (and, when
///   the SLO feedback layer is on, rolling TBT headroom) every
///   `trigger_check_period` seconds and fires an *incremental*
///   rebalance — only moves whose projected queued-token relief beats
///   their RDMA migration cost are applied.
/// * `Hybrid` — both: the periodic full re-place as a slow safety net,
///   with triggered incremental rebalances in between.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RebalanceMode {
    #[default]
    Periodic,
    Triggered,
    Hybrid,
}

impl RebalanceMode {
    /// Parse `periodic`, `triggered`, or `hybrid`.
    pub fn parse(s: &str) -> Result<RebalanceMode, String> {
        match s {
            "periodic" => Ok(RebalanceMode::Periodic),
            "triggered" => Ok(RebalanceMode::Triggered),
            "hybrid" => Ok(RebalanceMode::Hybrid),
            other => Err(format!(
                "unknown rebalance mode '{other}' (valid: periodic | \
                 triggered | hybrid)"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RebalanceMode::Periodic => "periodic",
            RebalanceMode::Triggered => "triggered",
            RebalanceMode::Hybrid => "hybrid",
        }
    }
}

/// Knobs of the drift-reactive placement layer
/// (`sim::rebalance::RebalanceTrigger` + the incremental migration
/// planner). JSON: `rebalance_mode`, `trigger_check_period`,
/// `trigger_imbalance`, `trigger_hysteresis`, `trigger_min_interval`,
/// `remote_attach`, `trigger_queue_signal`, `trigger_queue_depth`,
/// `trigger_stall`, `remote_promote_hot`; CLI: `--rebalance-mode`,
/// `--remote-attach`.
///
/// Defaults keep the layer fully inert: `Periodic` mode never
/// evaluates the trigger, never plans incrementally, and never serves
/// remotely — the engine is the PR 4 open-loop rebalancer bit for bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RebalanceConfig {
    pub mode: RebalanceMode,
    /// Seconds between trigger-signal evaluations (triggered/hybrid
    /// modes; this is also the demand tracker's window there).
    pub check_period: f64,
    /// Fire threshold on the projected per-server load-imbalance ratio
    /// (max utilization ÷ mean over active servers). Strictly > 1 —
    /// the ratio is floored at 1.0, so a threshold of exactly 1 would
    /// leave the hysteresis exit unreachable.
    pub imbalance_threshold: f64,
    /// Schmitt-trigger exit fraction in (0, 1], applied to the
    /// threshold's excess over 1 (the ratio's floor): once fired, the
    /// trigger re-arms only after the ratio falls below
    /// `1 + hysteresis × (imbalance_threshold − 1)`, so a signal
    /// hovering at the threshold cannot thrash.
    pub hysteresis: f64,
    /// Minimum seconds between triggered rebalances (paces re-fires
    /// while a fix takes effect).
    pub min_interval: f64,
    /// Serve cold/overflow adapters from a peer server's HBM over
    /// GPUDirect RDMA instead of migrating them: no fetch wait and no
    /// copy moved, but every iteration touching the adapter pays
    /// `ServerConfig::remote_attach_penalty`. Only meaningful with a
    /// distributed pool.
    pub remote_attach: bool,
    /// Feed queue pressure — mean pending depth over active servers
    /// and windowed fetch-stall seconds — into the trigger as a third
    /// OR-term beside the imbalance ratio and SLO headroom. Off by
    /// default: the trigger behaves exactly as before.
    pub queue_signal: bool,
    /// Mean pending requests per active server (ready queue + fetch
    /// waiters + active batch) that counts as queue pressure.
    pub queue_depth_hot: f64,
    /// Fleet-wide fetch-stall seconds accumulated since the previous
    /// trigger check that count as queue pressure.
    pub stall_hot: f64,
    /// Remote-attach promotion: an adapter remotely served from one
    /// server at least this many times between trigger checks gets its
    /// copy migrated there (stop paying the per-iteration RDMA penalty
    /// for sustained traffic). 0 (the default) disables promotion.
    /// Only meaningful with `remote_attach` in triggered/hybrid mode.
    pub promote_hot: u64,
    /// Feed HBM memory pressure — any active server's unified-pool
    /// page occupancy at or above `occupancy_hot` — into the trigger
    /// as a fourth OR-term. Off by default, and inert unless the pool
    /// is bounded (`ServerConfig::hbm_pages > 0`). JSON knob
    /// `trigger_memory_signal`.
    pub memory_signal: bool,
    /// Page-occupancy fraction (used ÷ total pages, in (0, 1]) at
    /// which one server counts as memory-pressed. JSON knob
    /// `trigger_occupancy`.
    pub occupancy_hot: f64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig {
            mode: RebalanceMode::Periodic,
            check_period: 15.0,
            imbalance_threshold: 1.5,
            hysteresis: 0.8,
            min_interval: 30.0,
            remote_attach: false,
            queue_signal: false,
            queue_depth_hot: 8.0,
            stall_hot: 0.5,
            promote_hot: 0,
            memory_signal: false,
            occupancy_hot: 0.9,
        }
    }
}

/// How `RankBucketed` picks the rank class that owns a prefill
/// iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClassSelect {
    /// The class with the most queued requests (ties to the class
    /// whose oldest request arrived first) — the original behavior,
    /// kept for comparison.
    #[default]
    LargestQueue,
    /// Cost-weighted: the class with the most queued *work* wins —
    /// queued prompt tokens ÷ the class's operating point (tokens/s
    /// under SLO), so a short queue of expensive high-rank prompts can
    /// outrank a long queue of cheap ones.
    CostWeighted,
}

/// Prefill admission policy of a server's continuous batching — the
/// *scheduler* half of the heterogeneous-rank design space (placement
/// is the other half). Every request in a batch pays the batch's
/// maximum adapter rank (the BGMV/MBGMV pad-to-max-rank kernels), so
/// what the admission loop lets into one iteration decides the
/// interference tax as much as where adapters live.
///
/// Implementations live in `sim::server` (the `BatchPolicy` trait);
/// this enum is the serializable knob threaded through configs, the
/// CLI (`--batch-policy`), the capacity planner, and the figure
/// harnesses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchPolicyKind {
    /// Strict arrival order (the S-LoRA/vLLM default; the pre-refactor
    /// simulator behavior, bit for bit).
    #[default]
    Fifo,
    /// Admit prefills from a single rank class per iteration, keeping
    /// batches rank-homogeneous. A queued head request is never passed
    /// over more than `max_wait_iters` consecutive prefill iterations
    /// (the bounded-wait starvation guard). `select` chooses how the
    /// winning class is picked.
    RankBucketed {
        max_wait_iters: u32,
        select: ClassSelect,
    },
    /// Admit in arrival order but skip requests whose rank would raise
    /// the batch maximum beyond `factor ×` the head request's rank.
    /// The head is always admitted, so nothing starves.
    RankCap { factor: u32 },
}

impl BatchPolicyKind {
    pub const DEFAULT_MAX_WAIT_ITERS: u32 = 8;
    pub const DEFAULT_CAP_FACTOR: u32 = 2;

    /// Parse `fifo`, `rank-bucketed[:W]`, `rank-bucketed-cost[:W]`, or
    /// `rank-cap[:F]`.
    pub fn parse(s: &str) -> Result<BatchPolicyKind, String> {
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (s, None),
        };
        let num = |p: Option<&str>, default: u32| -> Result<u32, String> {
            match p {
                None => Ok(default),
                Some(x) => x
                    .parse::<u32>()
                    .map_err(|e| format!("batch-policy param '{x}': {e}")),
            }
        };
        match name {
            "fifo" => {
                if param.is_some() {
                    return Err("fifo takes no parameter".into());
                }
                Ok(BatchPolicyKind::Fifo)
            }
            "rank-bucketed" | "bucketed" => Ok(BatchPolicyKind::RankBucketed {
                max_wait_iters: num(param, Self::DEFAULT_MAX_WAIT_ITERS)?,
                select: ClassSelect::LargestQueue,
            }),
            "rank-bucketed-cost" | "bucketed-cost" => {
                Ok(BatchPolicyKind::RankBucketed {
                    max_wait_iters: num(param, Self::DEFAULT_MAX_WAIT_ITERS)?,
                    select: ClassSelect::CostWeighted,
                })
            }
            "rank-cap" | "cap" => {
                let factor = num(param, Self::DEFAULT_CAP_FACTOR)?;
                if factor == 0 {
                    return Err("rank-cap factor must be >= 1".into());
                }
                Ok(BatchPolicyKind::RankCap { factor })
            }
            other => Err(format!(
                "unknown batch policy '{other}' (valid: fifo | \
                 rank-bucketed[:wait] | rank-bucketed-cost[:wait] \
                 | rank-cap[:factor])"
            )),
        }
    }

    pub fn label(&self) -> String {
        match self {
            BatchPolicyKind::Fifo => "fifo".into(),
            BatchPolicyKind::RankBucketed {
                max_wait_iters,
                select: ClassSelect::LargestQueue,
            } => format!("rank-bucketed:{max_wait_iters}"),
            BatchPolicyKind::RankBucketed {
                max_wait_iters,
                select: ClassSelect::CostWeighted,
            } => format!("rank-bucketed-cost:{max_wait_iters}"),
            BatchPolicyKind::RankCap { factor } => {
                format!("rank-cap:{factor}")
            }
        }
    }
}

/// Decode-set composition policy — the *other* phase of the scheduler
/// seam. Prefill admission decides what becomes active; this knob
/// decides how the active set is decoded each iteration: as one
/// pad-to-max-rank batch (the BGMV baseline) or as per-rank-class
/// sub-batch steps (SGMV-style grouped kernels, each step billed at
/// its own class's operating point plus a per-sub-batch launch
/// overhead — see `ServerConfig::decode_launch_overhead`).
///
/// Implementations live in `sim::server` (the `BatchPolicy` trait's
/// `compose_decode`); this enum is the serializable knob threaded
/// through configs, the CLI (`--decode-policy`), the capacity planner,
/// and the figure harnesses — symmetric with [`BatchPolicyKind`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodePolicyKind {
    /// One decode step over the whole active set at its maximum rank
    /// (the pre-refactor behavior, bit for bit).
    #[default]
    Unified,
    /// One sub-batch step per rank class present in the active set,
    /// every decode round: each class pays only its own rank's
    /// operating point (plus the launch overhead when the round has
    /// more than one sub-batch).
    RankPartitioned,
    /// At most `max_groups` rank classes decode per round, chosen by a
    /// cyclic fairness rotor over the classes present, bounding kernel
    /// launches per round: a non-empty class is never skipped for more
    /// than ⌈classes/max_groups⌉ − 1 consecutive rounds. Under SLO
    /// feedback the rotor becomes SLO-aware: the classes with the
    /// worst rolling TBT headroom go first, cyclic on ties.
    ClassSubBatch { max_groups: u32 },
    /// Adaptive `max_groups` from the launch-overhead/padding
    /// break-even (`CostModel::decode_split_gain`): each round, every
    /// rank class whose recovered padding beats one extra sub-batch
    /// launch decodes as its own group; the rest fold into the
    /// maximum-rank group. Collapses to `unified` when no split pays,
    /// to `rank-partitioned` when every split does.
    ClassSubBatchAuto,
}

impl DecodePolicyKind {
    pub const DEFAULT_MAX_GROUPS: u32 = 2;

    /// Parse `unified`, `rank-partitioned`, `class-subbatch[:G]`, or
    /// `class-subbatch:auto`.
    pub fn parse(s: &str) -> Result<DecodePolicyKind, String> {
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (s, None),
        };
        match name {
            "unified" => {
                if param.is_some() {
                    return Err("unified takes no parameter".into());
                }
                Ok(DecodePolicyKind::Unified)
            }
            "rank-partitioned" | "partitioned" => {
                if param.is_some() {
                    return Err(
                        "rank-partitioned takes no parameter".into()
                    );
                }
                Ok(DecodePolicyKind::RankPartitioned)
            }
            "class-subbatch" | "subbatch" => {
                let max_groups = match param {
                    None => Self::DEFAULT_MAX_GROUPS,
                    Some("auto") => {
                        return Ok(DecodePolicyKind::ClassSubBatchAuto)
                    }
                    Some(x) => x.parse::<u32>().map_err(|e| {
                        format!("decode-policy param '{x}': {e}")
                    })?,
                };
                if max_groups == 0 {
                    return Err(
                        "class-subbatch needs max_groups >= 1".into()
                    );
                }
                Ok(DecodePolicyKind::ClassSubBatch { max_groups })
            }
            other => Err(format!(
                "unknown decode policy '{other}' (valid: unified | \
                 rank-partitioned | class-subbatch[:groups] | \
                 class-subbatch:auto)"
            )),
        }
    }

    pub fn label(&self) -> String {
        match self {
            DecodePolicyKind::Unified => "unified".into(),
            DecodePolicyKind::RankPartitioned => {
                "rank-partitioned".into()
            }
            DecodePolicyKind::ClassSubBatch { max_groups } => {
                format!("class-subbatch:{max_groups}")
            }
            DecodePolicyKind::ClassSubBatchAuto => {
                "class-subbatch:auto".into()
            }
        }
    }
}

/// One LLM inference server (one base-model instance, possibly TP over
/// several GPUs) — the unit LORASERVE places adapters onto.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerConfig {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    pub tp: usize,
    /// Token budget of one continuous-batching iteration (prefill).
    pub max_batch_tokens: usize,
    /// Max concurrent decode slots.
    pub max_batch_size: usize,
    /// Host (CPU) memory available for resident adapters, bytes.
    pub host_mem_bytes: u64,
    /// GPU memory reserved for *active* adapter slices (S-LoRA's
    /// unified paging pool). Adapters outside this cache page in from
    /// host memory over PCIe before their batch can run — the cost
    /// that punishes scattering a wide working set across every server.
    pub gpu_adapter_cache_bytes: u64,
    /// Per-sub-batch kernel-launch overhead of grouped (SGMV-style)
    /// decode, seconds: every sub-batch step of a multi-group decode
    /// round pays this on top of its class's decode cost. A unified
    /// (single-group) decode pays nothing. JSON knob:
    /// `decode_launch_overhead_ms`.
    pub decode_launch_overhead: f64,
    /// Per-iteration penalty of touching one remotely-attached adapter
    /// (`RebalanceConfig::remote_attach`), seconds: the weights stay
    /// in a peer server's HBM and each iteration streams the active
    /// low-rank slices over GPUDirect RDMA instead of paging a local
    /// copy. Default derived from the `FetchSource::RemoteRdma` link
    /// model (see `costmodel::calib::REMOTE_ATTACH_PENALTY`). JSON
    /// knob: `remote_attach_penalty_ms`.
    pub remote_attach_penalty: f64,
    /// Unified paged HBM budget per server, in
    /// `costmodel::calib::HBM_PAGE_BYTES` pages, shared by adapter
    /// slices *and* per-request KV cache (`pool::hbm::HbmPool`). 0 (the
    /// default) keeps the pool unbounded: adapters use the legacy
    /// `gpu_adapter_cache_bytes` byte-LRU bit for bit and KV is never
    /// tracked — pre-refactor behavior exactly. JSON knob `hbm_pages`,
    /// CLI `--hbm-pages`.
    pub hbm_pages: usize,
    /// Victim selection when a bounded HBM pool must evict adapter
    /// pages (`hbm_pages > 0`; inert otherwise). JSON knob
    /// `evict_policy`, CLI `--evict-policy`.
    pub evict_policy: crate::pool::hbm::EvictPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            model: ModelSpec::LLAMA_7B,
            gpu: GpuSpec::A100_40G,
            tp: 4,
            // S-LoRA-generation serving stacks run modest iteration
            // budgets; these put single-server capacity at the paper's
            // regime (Fig 6: 4 RPS of 512/128 saturates high ranks).
            max_batch_tokens: 2048,
            max_batch_size: 24,
            host_mem_bytes: 900 * (1 << 30), // ND96asr_v4: 900 GiB host
            gpu_adapter_cache_bytes: (3 << 30) / 2, // ~1.5 GiB of HBM after weights+KV
            decode_launch_overhead:
                crate::costmodel::calib::DECODE_LAUNCH_OVERHEAD,
            remote_attach_penalty:
                crate::costmodel::calib::REMOTE_ATTACH_PENALTY,
            hbm_pages: 0,
            evict_policy: crate::pool::hbm::EvictPolicy::default(),
        }
    }
}

/// Cluster-level experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub n_servers: usize,
    pub server: ServerConfig,
    pub slo: SloConfig,
    /// Placement rebalance period in seconds (the paper's "time step",
    /// cluster-admin configurable, §IV).
    pub rebalance_period: f64,
    /// Elastic-capacity knobs; only consulted when a simulation is run
    /// with autoscaling enabled (`SimConfig::with_autoscale`).
    pub autoscale: AutoscaleConfig,
    /// Prefill admission policy of every simulated server's continuous
    /// batching (threaded into `SimConfig` and the capacity planner).
    pub batch_policy: BatchPolicyKind,
    /// Decode-set composition policy of every simulated server
    /// (threaded into `SimConfig` and the capacity planner, symmetric
    /// with `batch_policy`).
    pub decode_policy: DecodePolicyKind,
    /// Scheduler SLO feedback layer (per-server headroom tracking,
    /// preemptible decode rounds, SLO-aware rotor, adaptive waits).
    /// Disabled by default — the PR 3 open-loop scheduler bit for bit.
    pub feedback: SloFeedbackConfig,
    /// Drift-reactive rebalancing (trigger mode, thresholds, remote
    /// attach). Default `Periodic` — the PR 4 open-loop rebalancer bit
    /// for bit.
    pub rebalance: RebalanceConfig,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_servers: 4,
            server: ServerConfig::default(),
            slo: SloConfig::default(),
            rebalance_period: 60.0,
            autoscale: AutoscaleConfig::default(),
            batch_policy: BatchPolicyKind::default(),
            decode_policy: DecodePolicyKind::default(),
            feedback: SloFeedbackConfig::default(),
            rebalance: RebalanceConfig::default(),
            seed: 0,
        }
    }
}

impl ClusterConfig {
    /// Load from a JSON object; missing keys keep defaults. Shape:
    /// `{"n_servers": 4, "model": "llama-7b", "tp": 4,
    ///   "ttft_slo": 10.0, "rebalance_period": 60.0, ...}`
    pub fn from_json(v: &Json) -> Result<ClusterConfig, String> {
        let mut cfg = ClusterConfig::default();
        if let Some(n) = v.get("n_servers").and_then(Json::as_usize) {
            cfg.n_servers = n;
        }
        if let Some(name) = v.get("model").and_then(Json::as_str) {
            cfg.server.model = ModelSpec::by_name(name)
                .ok_or_else(|| format!("unknown model '{name}'"))?;
        }
        if let Some(gpu) = v.get("gpu").and_then(Json::as_str) {
            cfg.server.gpu = match gpu {
                "a100-40g" => GpuSpec::A100_40G,
                "a100-80g" => GpuSpec::A100_80G,
                other => return Err(format!("unknown gpu '{other}'")),
            };
        }
        if let Some(tp) = v.get("tp").and_then(Json::as_usize) {
            if !tp.is_power_of_two() || tp > 8 {
                return Err(format!("tp must be 1/2/4/8, got {tp}"));
            }
            cfg.server.tp = tp;
        }
        if let Some(x) = v.get("max_batch_tokens").and_then(Json::as_usize) {
            cfg.server.max_batch_tokens = x;
        }
        if let Some(x) = v.get("max_batch_size").and_then(Json::as_usize) {
            cfg.server.max_batch_size = x;
        }
        if let Some(x) = v.get("host_mem_gib").and_then(Json::as_f64) {
            cfg.server.host_mem_bytes = (x * (1u64 << 30) as f64) as u64;
        }
        if let Some(x) = v.get("ttft_slo").and_then(Json::as_f64) {
            cfg.slo.ttft_p95 = x;
        }
        if let Some(x) = v.get("e2e_slo").and_then(Json::as_f64) {
            cfg.slo.e2e_p95 = x;
        }
        if let Some(x) = v.get("timeout").and_then(Json::as_f64) {
            cfg.slo.timeout = x;
        }
        if let Some(x) = v.get("rebalance_period").and_then(Json::as_f64) {
            cfg.rebalance_period = x;
        }
        if let Some(s) = v.get("batch_policy").and_then(Json::as_str) {
            cfg.batch_policy = BatchPolicyKind::parse(s)?;
        }
        if let Some(s) = v.get("decode_policy").and_then(Json::as_str) {
            cfg.decode_policy = DecodePolicyKind::parse(s)?;
        }
        if let Some(x) = v.get("slo_ttft_ms").and_then(Json::as_f64) {
            if x <= 0.0 {
                return Err(format!("slo_ttft_ms must be > 0, got {x}"));
            }
            cfg.feedback.ttft_target = x / 1e3;
            cfg.feedback.enabled = true;
        }
        if let Some(x) = v.get("slo_tbt_ms").and_then(Json::as_f64) {
            if x <= 0.0 {
                return Err(format!("slo_tbt_ms must be > 0, got {x}"));
            }
            cfg.feedback.tbt_target = x / 1e3;
            cfg.feedback.enabled = true;
        }
        if let Some(b) = v.get("preempt_decode").and_then(Json::as_bool) {
            cfg.feedback.preempt_decode = b;
            if b {
                cfg.feedback.enabled = true;
            }
        }
        if let Some(x) =
            v.get("slo_pressure_theta").and_then(Json::as_f64)
        {
            if !(0.0..=1.0).contains(&x) {
                return Err(format!(
                    "slo_pressure_theta must be in [0, 1], got {x}"
                ));
            }
            cfg.feedback.pressure_theta = x;
            // like every sibling feedback knob: tuning it switches the
            // layer on (the targets have usable defaults), instead of
            // being silently inert
            cfg.feedback.enabled = true;
        }
        if let Some(x) =
            v.get("decode_launch_overhead_ms").and_then(Json::as_f64)
        {
            if x < 0.0 {
                return Err(format!(
                    "decode_launch_overhead_ms must be >= 0, got {x}"
                ));
            }
            cfg.server.decode_launch_overhead = x / 1e3;
        }
        if let Some(x) =
            v.get("remote_attach_penalty_ms").and_then(Json::as_f64)
        {
            if x < 0.0 {
                return Err(format!(
                    "remote_attach_penalty_ms must be >= 0, got {x}"
                ));
            }
            cfg.server.remote_attach_penalty = x / 1e3;
        }
        if let Some(s) = v.get("rebalance_mode").and_then(Json::as_str) {
            cfg.rebalance.mode = RebalanceMode::parse(s)?;
        }
        if let Some(x) =
            v.get("trigger_check_period").and_then(Json::as_f64)
        {
            if x <= 0.0 {
                return Err(format!(
                    "trigger_check_period must be > 0, got {x}"
                ));
            }
            cfg.rebalance.check_period = x;
        }
        if let Some(x) = v.get("trigger_imbalance").and_then(Json::as_f64)
        {
            // strictly above 1: the ratio is floored at 1.0, so a
            // threshold of exactly 1 has an unreachable hysteresis
            // exit and would latch the trigger after one fire
            if x <= 1.0 {
                return Err(format!(
                    "trigger_imbalance must be > 1, got {x}"
                ));
            }
            cfg.rebalance.imbalance_threshold = x;
        }
        if let Some(x) =
            v.get("trigger_hysteresis").and_then(Json::as_f64)
        {
            if !(0.0..=1.0).contains(&x) || x == 0.0 {
                return Err(format!(
                    "trigger_hysteresis must be in (0, 1], got {x}"
                ));
            }
            cfg.rebalance.hysteresis = x;
        }
        if let Some(x) =
            v.get("trigger_min_interval").and_then(Json::as_f64)
        {
            if x < 0.0 {
                return Err(format!(
                    "trigger_min_interval must be >= 0, got {x}"
                ));
            }
            cfg.rebalance.min_interval = x;
        }
        if let Some(b) = v.get("remote_attach").and_then(Json::as_bool) {
            cfg.rebalance.remote_attach = b;
        }
        if let Some(b) =
            v.get("trigger_queue_signal").and_then(Json::as_bool)
        {
            cfg.rebalance.queue_signal = b;
        }
        if let Some(x) =
            v.get("trigger_queue_depth").and_then(Json::as_f64)
        {
            if x <= 0.0 {
                return Err(format!(
                    "trigger_queue_depth must be > 0, got {x}"
                ));
            }
            cfg.rebalance.queue_depth_hot = x;
        }
        if let Some(x) = v.get("trigger_stall").and_then(Json::as_f64) {
            if x <= 0.0 {
                return Err(format!(
                    "trigger_stall must be > 0, got {x}"
                ));
            }
            cfg.rebalance.stall_hot = x;
        }
        if let Some(x) =
            v.get("remote_promote_hot").and_then(Json::as_usize)
        {
            cfg.rebalance.promote_hot = x as u64;
        }
        if let Some(x) = v.get("hbm_pages").and_then(Json::as_usize) {
            cfg.server.hbm_pages = x;
        }
        if let Some(s) = v.get("evict_policy").and_then(Json::as_str) {
            cfg.server.evict_policy =
                crate::pool::hbm::EvictPolicy::parse(s).ok_or_else(
                    || {
                        format!(
                            "unknown evict_policy '{s}' \
                             (lru | rank-weighted | slo-aware)"
                        )
                    },
                )?;
        }
        if let Some(b) =
            v.get("trigger_memory_signal").and_then(Json::as_bool)
        {
            cfg.rebalance.memory_signal = b;
        }
        if let Some(x) = v.get("trigger_occupancy").and_then(Json::as_f64)
        {
            if !(0.0..=1.0).contains(&x) || x == 0.0 {
                return Err(format!(
                    "trigger_occupancy must be in (0, 1], got {x}"
                ));
            }
            cfg.rebalance.occupancy_hot = x;
        }
        if let Some(a) = v.get("autoscale") {
            let au = &mut cfg.autoscale;
            if let Some(x) = a.get("min_servers").and_then(Json::as_usize) {
                au.min_servers = x;
            }
            if let Some(x) = a.get("max_servers").and_then(Json::as_usize) {
                au.max_servers = x;
            }
            if let Some(x) = a.get("decision_period").and_then(Json::as_f64) {
                au.decision_period = x;
            }
            if let Some(x) = a.get("scale_up_util").and_then(Json::as_f64) {
                au.scale_up_util = x;
            }
            if let Some(x) = a.get("scale_down_util").and_then(Json::as_f64) {
                au.scale_down_util = x;
            }
            if let Some(x) = a.get("violation_rate_up").and_then(Json::as_f64)
            {
                au.violation_rate_up = x;
            }
            if let Some(x) = a.get("cooldown").and_then(Json::as_f64) {
                au.cooldown = x;
            }
            if let Some(x) = a.get("provision_delay").and_then(Json::as_f64) {
                au.provision_delay = x;
            }
            if au.min_servers == 0
                || au.max_servers < au.min_servers
                || au.decision_period <= 0.0
                || au.scale_down_util >= au.scale_up_util
                || au.cooldown < 0.0
                || au.provision_delay < 0.0
                || au.violation_rate_up < 0.0
            {
                return Err(format!(
                    "bad autoscale config: min={} max={} period={} \
                     up={} down={} cooldown={} delay={} violations={} \
                     (need min>=1, max>=min, period>0, down<up, \
                     non-negative times/rates)",
                    au.min_servers,
                    au.max_servers,
                    au.decision_period,
                    au.scale_up_util,
                    au.scale_down_util,
                    au.cooldown,
                    au.provision_delay,
                    au.violation_rate_up
                ));
            }
        }
        if let Some(x) = v.get("seed").and_then(Json::as_f64) {
            cfg.seed = x as u64;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<ClusterConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{path}: {e}"))?;
        let v = json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&v)
    }

    /// Total GPUs in the cluster (the resource the paper's "50% fewer
    /// GPUs" claim counts).
    pub fn total_gpus(&self) -> usize {
        self.n_servers * self.server.tp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adapter_bytes_matches_paper_scale() {
        // 7B fp16, rank 64: 8*4096*64*32 params * 2 B ≈ 134 MB.
        let b = ModelSpec::LLAMA_7B.adapter_bytes(64);
        assert_eq!(b, 8 * 4096 * 64 * 32 * 2);
        // ranks scale linearly
        assert_eq!(
            ModelSpec::LLAMA_7B.adapter_bytes(128),
            2 * ModelSpec::LLAMA_7B.adapter_bytes(64)
        );
        // adapters are ~1-2% of base weights at rank 128 (paper §I)
        let frac = ModelSpec::LLAMA_7B.adapter_bytes(128) as f64
            / ModelSpec::LLAMA_7B.weight_bytes();
        assert!(frac > 0.005 && frac < 0.05, "frac={frac}");
    }

    #[test]
    fn model_lookup() {
        assert_eq!(
            ModelSpec::by_name("llama-70b").unwrap().n_layers,
            80
        );
        assert_eq!(ModelSpec::by_name("7b").unwrap().d_model, 4096);
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }

    #[test]
    fn config_from_json() {
        let v = json::parse(
            r#"{"n_servers": 8, "model": "llama-30b", "tp": 8,
                "ttft_slo": 20.0, "rebalance_period": 30.0,
                "host_mem_gib": 220.0, "seed": 7}"#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_json(&v).unwrap();
        assert_eq!(cfg.n_servers, 8);
        assert_eq!(cfg.server.model.name, "llama-30b");
        assert_eq!(cfg.server.tp, 8);
        assert_eq!(cfg.slo.ttft_p95, 20.0);
        assert_eq!(cfg.rebalance_period, 30.0);
        assert_eq!(cfg.server.host_mem_bytes, 220 * (1 << 30));
        assert_eq!(cfg.total_gpus(), 64);
    }

    #[test]
    fn config_rejects_bad_values() {
        let v = json::parse(r#"{"tp": 3}"#).unwrap();
        assert!(ClusterConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"model": "nope"}"#).unwrap();
        assert!(ClusterConfig::from_json(&v).is_err());
        let v = json::parse(
            r#"{"autoscale": {"min_servers": 4, "max_servers": 2}}"#,
        )
        .unwrap();
        assert!(ClusterConfig::from_json(&v).is_err());
        // inverted hysteresis thresholds make the controller oscillate
        let v = json::parse(
            r#"{"autoscale": {"scale_up_util": 0.3,
                              "scale_down_util": 0.8}}"#,
        )
        .unwrap();
        assert!(ClusterConfig::from_json(&v).is_err());
        let v =
            json::parse(r#"{"autoscale": {"cooldown": -5.0}}"#).unwrap();
        assert!(ClusterConfig::from_json(&v).is_err());
    }

    #[test]
    fn autoscale_config_from_json() {
        let v = json::parse(
            r#"{"e2e_slo": 30.0,
                "autoscale": {"min_servers": 2, "max_servers": 10,
                              "decision_period": 5.0, "cooldown": 45.0,
                              "scale_up_util": 0.9,
                              "provision_delay": 12.0}}"#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_json(&v).unwrap();
        assert_eq!(cfg.slo.e2e_p95, 30.0);
        assert_eq!(cfg.autoscale.min_servers, 2);
        assert_eq!(cfg.autoscale.max_servers, 10);
        assert_eq!(cfg.autoscale.decision_period, 5.0);
        assert_eq!(cfg.autoscale.cooldown, 45.0);
        assert_eq!(cfg.autoscale.scale_up_util, 0.9);
        assert_eq!(cfg.autoscale.provision_delay, 12.0);
        // untouched knobs keep defaults
        assert_eq!(
            cfg.autoscale.scale_down_util,
            AutoscaleConfig::default().scale_down_util
        );
        assert!(SloConfig::default().e2e_p95.is_infinite());
    }

    #[test]
    fn batch_policy_parse_and_label() {
        assert_eq!(
            BatchPolicyKind::parse("fifo").unwrap(),
            BatchPolicyKind::Fifo
        );
        assert_eq!(
            BatchPolicyKind::parse("rank-bucketed").unwrap(),
            BatchPolicyKind::RankBucketed {
                max_wait_iters: BatchPolicyKind::DEFAULT_MAX_WAIT_ITERS,
                select: ClassSelect::LargestQueue,
            }
        );
        assert_eq!(
            BatchPolicyKind::parse("rank-bucketed:3").unwrap(),
            BatchPolicyKind::RankBucketed {
                max_wait_iters: 3,
                select: ClassSelect::LargestQueue,
            }
        );
        assert_eq!(
            BatchPolicyKind::parse("rank-bucketed-cost:6").unwrap(),
            BatchPolicyKind::RankBucketed {
                max_wait_iters: 6,
                select: ClassSelect::CostWeighted,
            }
        );
        assert_eq!(
            BatchPolicyKind::parse("rank-cap:4").unwrap(),
            BatchPolicyKind::RankCap { factor: 4 }
        );
        assert!(BatchPolicyKind::parse("rank-cap:0").is_err());
        assert!(BatchPolicyKind::parse("fifo:1").is_err());
        assert!(BatchPolicyKind::parse("lifo").is_err());
        assert!(BatchPolicyKind::parse("rank-cap:x").is_err());
        // labels round-trip through parse
        for k in [
            BatchPolicyKind::Fifo,
            BatchPolicyKind::RankBucketed {
                max_wait_iters: 5,
                select: ClassSelect::LargestQueue,
            },
            BatchPolicyKind::RankBucketed {
                max_wait_iters: 5,
                select: ClassSelect::CostWeighted,
            },
            BatchPolicyKind::RankCap { factor: 2 },
        ] {
            assert_eq!(BatchPolicyKind::parse(&k.label()).unwrap(), k);
        }
    }

    #[test]
    fn decode_policy_parse_and_label() {
        assert_eq!(
            DecodePolicyKind::parse("unified").unwrap(),
            DecodePolicyKind::Unified
        );
        assert_eq!(
            DecodePolicyKind::parse("rank-partitioned").unwrap(),
            DecodePolicyKind::RankPartitioned
        );
        assert_eq!(
            DecodePolicyKind::parse("partitioned").unwrap(),
            DecodePolicyKind::RankPartitioned
        );
        assert_eq!(
            DecodePolicyKind::parse("class-subbatch").unwrap(),
            DecodePolicyKind::ClassSubBatch {
                max_groups: DecodePolicyKind::DEFAULT_MAX_GROUPS
            }
        );
        assert_eq!(
            DecodePolicyKind::parse("class-subbatch:3").unwrap(),
            DecodePolicyKind::ClassSubBatch { max_groups: 3 }
        );
        assert!(DecodePolicyKind::parse("class-subbatch:0").is_err());
        assert!(DecodePolicyKind::parse("unified:1").is_err());
        assert!(DecodePolicyKind::parse("rank-partitioned:2").is_err());
        assert!(DecodePolicyKind::parse("nope").is_err());
        assert!(DecodePolicyKind::parse("class-subbatch:x").is_err());
        // the adaptive (break-even) composition parses and labels
        assert_eq!(
            DecodePolicyKind::parse("class-subbatch:auto").unwrap(),
            DecodePolicyKind::ClassSubBatchAuto
        );
        // labels round-trip through parse
        for k in [
            DecodePolicyKind::Unified,
            DecodePolicyKind::RankPartitioned,
            DecodePolicyKind::ClassSubBatch { max_groups: 4 },
            DecodePolicyKind::ClassSubBatchAuto,
        ] {
            assert_eq!(DecodePolicyKind::parse(&k.label()).unwrap(), k);
        }
        // default is unified (the paper's baseline decode path)
        assert_eq!(
            ClusterConfig::default().decode_policy,
            DecodePolicyKind::Unified
        );
    }

    #[test]
    fn decode_policy_from_json() {
        let v = json::parse(
            r#"{"decode_policy": "class-subbatch:3",
                "decode_launch_overhead_ms": 1.5}"#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_json(&v).unwrap();
        assert_eq!(
            cfg.decode_policy,
            DecodePolicyKind::ClassSubBatch { max_groups: 3 }
        );
        assert!((cfg.server.decode_launch_overhead - 1.5e-3).abs() < 1e-12);
        let v = json::parse(r#"{"decode_policy": "nope"}"#).unwrap();
        assert!(ClusterConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"decode_launch_overhead_ms": -1.0}"#)
            .unwrap();
        assert!(ClusterConfig::from_json(&v).is_err());
        // untouched: the default overhead comes from calib
        assert_eq!(
            ClusterConfig::default().server.decode_launch_overhead,
            crate::costmodel::calib::DECODE_LAUNCH_OVERHEAD
        );
    }

    #[test]
    fn batch_policy_from_json() {
        let v = json::parse(r#"{"batch_policy": "rank-cap:3"}"#).unwrap();
        let cfg = ClusterConfig::from_json(&v).unwrap();
        assert_eq!(cfg.batch_policy, BatchPolicyKind::RankCap { factor: 3 });
        let v = json::parse(r#"{"batch_policy": "nope"}"#).unwrap();
        assert!(ClusterConfig::from_json(&v).is_err());
        // default is fifo (the paper's baseline scheduler)
        assert_eq!(
            ClusterConfig::default().batch_policy,
            BatchPolicyKind::Fifo
        );
    }

    /// Unknown policy names list every valid variant (mirroring the
    /// `--system` registry-listing error).
    #[test]
    fn unknown_policy_errors_list_variants() {
        let e = BatchPolicyKind::parse("lifo").unwrap_err();
        for v in ["fifo", "rank-bucketed", "rank-bucketed-cost", "rank-cap"]
        {
            assert!(e.contains(v), "batch error misses '{v}': {e}");
        }
        let e = DecodePolicyKind::parse("nope").unwrap_err();
        for v in [
            "unified",
            "rank-partitioned",
            "class-subbatch[:groups]",
            "class-subbatch:auto",
        ] {
            assert!(e.contains(v), "decode error misses '{v}': {e}");
        }
    }

    #[test]
    fn slo_feedback_from_json() {
        // defaults: disabled, open loop
        let cfg = ClusterConfig::default();
        assert!(!cfg.feedback.enabled);
        assert!(!cfg.feedback.preempt_decode);
        // any feedback knob enables the layer
        let v = json::parse(
            r#"{"slo_ttft_ms": 150.0, "slo_tbt_ms": 80.0,
                "preempt_decode": true, "slo_pressure_theta": 0.8}"#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_json(&v).unwrap();
        assert!(cfg.feedback.enabled);
        assert!(cfg.feedback.preempt_decode);
        assert!((cfg.feedback.ttft_target - 0.15).abs() < 1e-12);
        assert!((cfg.feedback.tbt_target - 0.08).abs() < 1e-12);
        assert!((cfg.feedback.pressure_theta - 0.8).abs() < 1e-12);
        // theta alone also enables (never a silently inert knob)
        let v = json::parse(r#"{"slo_pressure_theta": 0.9}"#).unwrap();
        let cfg = ClusterConfig::from_json(&v).unwrap();
        assert!(cfg.feedback.enabled);
        assert!(!cfg.feedback.preempt_decode);
        // preempt off alone keeps the layer disabled
        let v = json::parse(r#"{"preempt_decode": false}"#).unwrap();
        let cfg = ClusterConfig::from_json(&v).unwrap();
        assert!(!cfg.feedback.enabled);
        // bad values rejected
        for bad in [
            r#"{"slo_ttft_ms": 0.0}"#,
            r#"{"slo_tbt_ms": -1.0}"#,
            r#"{"slo_pressure_theta": 1.5}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(ClusterConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn rebalance_config_from_json() {
        // defaults: periodic, inert
        let cfg = ClusterConfig::default();
        assert_eq!(cfg.rebalance.mode, RebalanceMode::Periodic);
        assert!(!cfg.rebalance.remote_attach);
        assert!(!cfg.rebalance.queue_signal);
        assert_eq!(cfg.rebalance.promote_hot, 0);
        let v = json::parse(
            r#"{"rebalance_mode": "triggered",
                "trigger_check_period": 10.0,
                "trigger_imbalance": 1.3,
                "trigger_hysteresis": 0.9,
                "trigger_min_interval": 20.0,
                "trigger_queue_signal": true,
                "trigger_queue_depth": 6.0,
                "trigger_stall": 0.25,
                "remote_attach": true,
                "remote_promote_hot": 3,
                "remote_attach_penalty_ms": 0.6}"#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_json(&v).unwrap();
        assert_eq!(cfg.rebalance.mode, RebalanceMode::Triggered);
        assert_eq!(cfg.rebalance.check_period, 10.0);
        assert_eq!(cfg.rebalance.imbalance_threshold, 1.3);
        assert_eq!(cfg.rebalance.hysteresis, 0.9);
        assert_eq!(cfg.rebalance.min_interval, 20.0);
        assert!(cfg.rebalance.remote_attach);
        assert!(cfg.rebalance.queue_signal);
        assert_eq!(cfg.rebalance.queue_depth_hot, 6.0);
        assert_eq!(cfg.rebalance.stall_hot, 0.25);
        assert_eq!(cfg.rebalance.promote_hot, 3);
        assert!(
            (cfg.server.remote_attach_penalty - 0.6e-3).abs() < 1e-12
        );
        // labels round-trip through parse, bad values rejected
        for m in [
            RebalanceMode::Periodic,
            RebalanceMode::Triggered,
            RebalanceMode::Hybrid,
        ] {
            assert_eq!(RebalanceMode::parse(m.label()).unwrap(), m);
        }
        let e = RebalanceMode::parse("nope").unwrap_err();
        for m in ["periodic", "triggered", "hybrid"] {
            assert!(e.contains(m), "error misses '{m}': {e}");
        }
        for bad in [
            r#"{"rebalance_mode": "sometimes"}"#,
            r#"{"trigger_check_period": 0.0}"#,
            r#"{"trigger_imbalance": 0.8}"#,
            r#"{"trigger_imbalance": 1.0}"#,
            r#"{"trigger_hysteresis": 0.0}"#,
            r#"{"trigger_hysteresis": 1.5}"#,
            r#"{"trigger_min_interval": -1.0}"#,
            r#"{"trigger_queue_depth": 0.0}"#,
            r#"{"trigger_stall": -0.5}"#,
            r#"{"remote_attach_penalty_ms": -0.1}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(ClusterConfig::from_json(&v).is_err(), "{bad}");
        }
        // untouched: the default penalty comes from calib
        assert_eq!(
            ClusterConfig::default().server.remote_attach_penalty,
            crate::costmodel::calib::REMOTE_ATTACH_PENALTY
        );
    }

    #[test]
    fn hbm_config_from_json() {
        use crate::pool::hbm::EvictPolicy;
        // defaults: unbounded pool, LRU, memory signal off
        let cfg = ClusterConfig::default();
        assert_eq!(cfg.server.hbm_pages, 0);
        assert_eq!(cfg.server.evict_policy, EvictPolicy::Lru);
        assert!(!cfg.rebalance.memory_signal);
        assert_eq!(cfg.rebalance.occupancy_hot, 0.9);
        let v = json::parse(
            r#"{"hbm_pages": 2048,
                "evict_policy": "rank-weighted",
                "trigger_memory_signal": true,
                "trigger_occupancy": 0.8}"#,
        )
        .unwrap();
        let cfg = ClusterConfig::from_json(&v).unwrap();
        assert_eq!(cfg.server.hbm_pages, 2048);
        assert_eq!(
            cfg.server.evict_policy,
            EvictPolicy::RankWeighted
        );
        assert!(cfg.rebalance.memory_signal);
        assert_eq!(cfg.rebalance.occupancy_hot, 0.8);
        // labels round-trip through parse, bad values rejected
        for p in [
            EvictPolicy::Lru,
            EvictPolicy::RankWeighted,
            EvictPolicy::SloAware,
        ] {
            assert_eq!(EvictPolicy::parse(p.label()).unwrap(), p);
        }
        for bad in [
            r#"{"evict_policy": "random"}"#,
            r#"{"trigger_occupancy": 0.0}"#,
            r#"{"trigger_occupancy": 1.5}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(ClusterConfig::from_json(&v).is_err(), "{bad}");
        }
        let v = json::parse(r#"{"evict_policy": "nope"}"#).unwrap();
        let e = ClusterConfig::from_json(&v).unwrap_err();
        for p in ["lru", "rank-weighted", "slo-aware"] {
            assert!(e.contains(p), "error misses '{p}': {e}");
        }
    }

    #[test]
    fn kv_bytes_per_token() {
        // 7B: 2 * 32 * 4096 * 2 = 512 KiB/token
        let kv = ModelSpec::LLAMA_7B.kv_bytes_per_token();
        assert_eq!(kv, 2.0 * 32.0 * 4096.0 * 2.0);
    }
}
