//! SLO feedback-layer invariants: request conservation across
//! preempted decode rounds, bit-exact parity of `unified` +
//! `--preempt-decode off` with the open-loop (PR 3) engine, the
//! SLO-aware rotor's fairness bound when all headrooms tie, and the
//! acceptance criterion on the bursty skewed-rank `sched_slo` trace —
//! preemptible decode + feedback beats the best open-loop policy on
//! P99 TTFT without giving up more than 2% aggregate throughput.
//!
//! (That no decode step ever runs with an empty sub-batch is enforced
//! by debug assertions on the hot path — `cargo test` runs the dev
//! profile, so every simulation in this suite exercises them.)

use loraserve::config::{
    BatchPolicyKind, ClusterConfig, DecodePolicyKind, ServerConfig,
    SloFeedbackConfig,
};
use loraserve::costmodel::CostModel;
use loraserve::figures::sched::{
    bursty_slo_trace, sched_slo_table, slo_grid_feedback,
};
use loraserve::sim::server::{
    ActiveReq, BatchPolicy, ClassSubBatchDecode, Fifo, SimReq,
};
use loraserve::sim::{self, SimConfig, SimReport, SloTracker, SystemKind};
use loraserve::trace::Trace;
use loraserve::workload::Request;
use std::collections::BTreeSet;

fn cluster() -> ClusterConfig {
    ClusterConfig {
        n_servers: 1,
        rebalance_period: 30.0,
        ..Default::default()
    }
}

fn run_one(
    trace: &Trace,
    batch: BatchPolicyKind,
    decode: DecodePolicyKind,
    feedback: Option<SloFeedbackConfig>,
) -> SimReport {
    let mut cfg = SimConfig::new(cluster(), SystemKind::SLoraRandom)
        .with_params(|p| p.batch(batch).decode(decode))
        .with_warmup(2.0);
    if let Some(f) = feedback {
        cfg = cfg.with_params(|p| p.slo(f));
    }
    sim::run(trace, &cfg)
}

/// `unified` decode with the tracker on but preemption off must
/// reproduce the open-loop engine bit for bit: the feedback layer is
/// purely observational until a knob acts (the PR 3 parity contract).
#[test]
fn unified_preempt_off_is_bit_identical_to_open_loop() {
    let trace = bursty_slo_trace(3, 45.0);
    let open = run_one(
        &trace,
        BatchPolicyKind::Fifo,
        DecodePolicyKind::Unified,
        None,
    );
    let tracked = run_one(
        &trace,
        BatchPolicyKind::Fifo,
        DecodePolicyKind::Unified,
        Some(SloFeedbackConfig {
            enabled: true,
            ttft_target: 0.1,
            tbt_target: 0.05,
            preempt_decode: false, // --preempt-decode off
            pressure_theta: 0.95,
        }),
    );
    assert_eq!(open.completed, tracked.completed);
    assert_eq!(open.timeouts, tracked.timeouts);
    assert_eq!(open.iters, tracked.iters);
    assert_eq!(open.decode_steps, tracked.decode_steps);
    assert_eq!(
        open.makespan.to_bits(),
        tracked.makespan.to_bits(),
        "tracking alone must not perturb simulated time"
    );
    assert_eq!(open.ttft.values(), tracked.ttft.values());
    assert_eq!(open.tbt.values(), tracked.tbt.values());
    assert_eq!(open.e2e.values(), tracked.e2e.values());
    assert_eq!(tracked.decode_preemptions, 0);
    // the observational streams do fill in — the layer was live
    assert!(!tracked.ttft_headroom.is_empty());
    assert!(open.ttft_headroom.is_empty());
}

/// Conservation across preempted rounds: with preemption firing, every
/// request still completes (or times out) exactly once, nothing is
/// lost, and the run stays deterministic per seed.
#[test]
fn preempted_rounds_conserve_requests() {
    let trace = bursty_slo_trace(5, 45.0);
    let rep = run_one(
        &trace,
        BatchPolicyKind::Fifo,
        DecodePolicyKind::RankPartitioned,
        Some(slo_grid_feedback()),
    );
    assert!(
        rep.decode_preemptions > 0,
        "bursts against a standing multi-step round must preempt"
    );
    assert_eq!(
        rep.completed + rep.timeouts,
        trace.requests.len() as u64,
        "requests lost across preempted rounds"
    );
    assert_eq!(rep.timeouts, 0, "nothing queues long enough to drop");
    assert!(rep.decode_steps > 0);
    assert!(
        !rep.ttft_under_pressure.is_empty(),
        "preempting admissions must be flagged"
    );
    // deterministic per (trace, config, seed)
    let rep2 = run_one(
        &trace,
        BatchPolicyKind::Fifo,
        DecodePolicyKind::RankPartitioned,
        Some(slo_grid_feedback()),
    );
    assert_eq!(rep.completed, rep2.completed);
    assert_eq!(rep.decode_preemptions, rep2.decode_preemptions);
    assert_eq!(rep.makespan.to_bits(), rep2.makespan.to_bits());
}

fn active_set(ranks: &[u32]) -> Vec<ActiveReq> {
    ranks
        .iter()
        .enumerate()
        .map(|(i, &rank)| ActiveReq {
            sreq: SimReq {
                req: Request {
                    id: i as u64,
                    adapter: i as u32,
                    prompt_len: 64,
                    output_len: 8,
                    arrival: 0.0,
                },
                rank,
                adapter_bytes: 1 << 20,
                est: 0.1,
                remote: false,
                uid: 0,
            },
            produced: 1,
            first_token_at: 0.0,
            seq: i as u64,
        })
        .collect()
}

fn rank_of(active: &[ActiveReq], seq: u64) -> u32 {
    active.iter().find(|a| a.seq == seq).unwrap().sreq.rank
}

/// Property: with a live tracker whose per-class headrooms all tie —
/// an all-fresh tracker, and one fed identical cadences — the
/// SLO-aware rotor degrades to the cyclic rotor, so no class is ever
/// skipped more than ⌈C/G⌉ − 1 consecutive rounds.
#[test]
fn slo_rotor_fairness_bound_when_headrooms_tie() {
    let cm = CostModel::new(ServerConfig::default());
    let ranks = [8u32, 16, 32, 64, 128];
    let mut members = Vec::new();
    for &r in &ranks {
        members.push(r);
        members.push(r);
    }
    let active = active_set(&members);
    let n_classes = ranks.len();
    for fresh in [true, false] {
        for k in [1usize, 2, 3] {
            let bound = n_classes.div_ceil(k);
            let mut tracker = SloTracker::new(slo_grid_feedback());
            let mut pol = ClassSubBatchDecode::new(Box::new(Fifo), k);
            let mut waited =
                std::collections::BTreeMap::<u32, usize>::new();
            for round in 0..30 {
                let now = 0.01 * round as f64;
                if !fresh {
                    // identical cadence for every class: headrooms tie
                    tracker.record_decode_step(now, ranks);
                }
                let plan = pol.compose_decode(
                    &active,
                    24,
                    &cm,
                    Some(&tracker),
                );
                assert!(plan.groups.len() <= k);
                let served: BTreeSet<u32> = plan
                    .groups
                    .iter()
                    .map(|g| rank_of(&active, g.seqs[0]))
                    .collect();
                for &rank in &ranks {
                    if served.contains(&rank) {
                        waited.insert(rank, 0);
                    } else {
                        let w = waited.entry(rank).or_insert(0);
                        *w += 1;
                        assert!(
                            *w < bound,
                            "fresh={fresh} k={k} round={round}: class \
                             {rank} skipped {w} rounds (bound {bound})"
                        );
                    }
                }
            }
        }
    }
}

/// The acceptance criterion behind this PR: on the bursty skewed-rank
/// `sched_slo` trace, preemptible decode + SLO feedback improves P99
/// TTFT over the *best* open-loop policy, without regressing
/// aggregate throughput by more than 2%.
#[test]
fn feedback_beats_best_open_loop_p99_ttft() {
    let trace = bursty_slo_trace(0, 90.0);
    let open_loop = [
        DecodePolicyKind::Unified,
        DecodePolicyKind::RankPartitioned,
        DecodePolicyKind::ClassSubBatch { max_groups: 2 },
    ];
    let mut best_p99 = f64::INFINITY;
    let mut best_thr: f64 = 0.0;
    for decode in open_loop {
        let mut rep =
            run_one(&trace, BatchPolicyKind::Fifo, decode, None);
        assert_eq!(
            rep.completed + rep.timeouts,
            trace.requests.len() as u64,
            "{}: requests lost",
            decode.label()
        );
        assert_eq!(rep.decode_preemptions, 0, "{}", decode.label());
        best_p99 = best_p99.min(rep.ttft.p99());
        best_thr = best_thr.max(rep.throughput_rps());
    }
    let mut fb = run_one(
        &trace,
        BatchPolicyKind::Fifo,
        DecodePolicyKind::RankPartitioned,
        Some(slo_grid_feedback()),
    );
    assert_eq!(
        fb.completed + fb.timeouts,
        trace.requests.len() as u64
    );
    assert!(fb.decode_preemptions > 0, "feedback never preempted");
    let fb_p99 = fb.ttft.p99();
    assert!(
        fb_p99 < best_p99,
        "feedback p99 TTFT {fb_p99} !< best open-loop {best_p99}"
    );
    assert!(
        fb.throughput_rps() >= 0.98 * best_thr,
        "throughput regressed > 2%: feedback {} vs best open-loop {}",
        fb.throughput_rps(),
        best_thr
    );
}

/// The `sched_slo` figure harness renders the full grid on a small
/// trace (the CI smoke surface for the feedback knobs).
#[test]
fn sched_slo_figure_smoke_run() {
    let trace = bursty_slo_trace(1, 30.0);
    let table = sched_slo_table(&trace, &cluster());
    assert_eq!(table.rows.len(), 6, "3 open-loop + 3 feedback rows");
    for row in &table.rows {
        for cell in row {
            assert!(!cell.is_empty(), "empty cell in {row:?}");
        }
    }
    let md = table.to_markdown();
    assert!(md.contains("open-loop"));
    assert!(md.contains("preempt+slo"));
    assert!(md.contains("class-subbatch:auto"));
    assert!(md.contains("rank-partitioned"));
    // the feedback rows actually preempted on this trace: the preempts
    // column is non-zero somewhere
    let preempted = table
        .rows
        .iter()
        .any(|r| r[7].parse::<u64>().unwrap_or(0) > 0);
    assert!(preempted, "no row preempted:\n{md}");
}
