//! The epoch-barrier determinism contract: the same (trace, config,
//! seed) produces a byte-identical report digest at ANY shard count —
//! sequential (shards=1) vs sharded (2, 8, or more threads than
//! servers). Sharding may only change who executes each lane's
//! identical computation, never what is computed or in what order
//! results are absorbed. Covers every canned system, drift/bursty
//! traces with triggered rebalancing and remote attach, elastic
//! (autoscale + drain) runs, and runs with the observability exports
//! enabled.

use loraserve::config::{
    AutoscaleConfig, ClusterConfig, RebalanceMode,
};
use loraserve::sim::{self, SimConfig, SystemKind};
use loraserve::trace::azure::{self, AzureConfig, RankPopularity};
use loraserve::trace::{LengthModel, Trace};

fn uniform_trace(rps: f64, seed: u64) -> Trace {
    azure::generate(&AzureConfig {
        rps,
        duration: 120.0,
        seed,
        lengths: LengthModel::fixed(256, 16),
        ..Default::default()
    })
}

fn bursty_trace(rps: f64, seed: u64) -> Trace {
    azure::generate(&AzureConfig {
        popularity: RankPopularity::ShiftingSkew,
        rps,
        duration: 180.0,
        seed,
        ..Default::default()
    })
}

fn cluster(n: usize) -> ClusterConfig {
    ClusterConfig {
        n_servers: n,
        rebalance_period: 20.0,
        ..Default::default()
    }
}

/// Run sequentially, then at several shard counts (including more
/// shards than servers), and require byte-identical digests.
fn assert_shard_invariant(trace: &Trace, base: &SimConfig, label: &str) {
    let mut seq = sim::run(trace, &base.clone().with_shards(1));
    let want = seq.to_json_string();
    assert!(seq.events > 0, "{label}: no events counted");
    for shards in [2usize, 8, 64] {
        let mut rep =
            sim::run(trace, &base.clone().with_shards(shards));
        assert_eq!(
            want,
            rep.to_json_string(),
            "{label}: digest diverged at shards={shards}"
        );
    }
}

#[test]
fn all_systems_shard_invariant() {
    let trace = uniform_trace(10.0, 1);
    for system in SystemKind::all() {
        let cfg = SimConfig::new(cluster(4), system);
        assert_shard_invariant(&trace, &cfg, system.label());
    }
}

#[test]
fn drift_trace_triggered_rebalance_shard_invariant() {
    let trace = bursty_trace(12.0, 2);
    for mode in [RebalanceMode::Triggered, RebalanceMode::Hybrid] {
        let mut c = cluster(4);
        c.rebalance.mode = mode;
        let cfg = SimConfig::new(c, SystemKind::LoraServe);
        assert_shard_invariant(
            &trace,
            &cfg,
            &format!("loraserve/{}", mode.label()),
        );
    }
}

#[test]
fn remote_attach_shard_invariant() {
    let trace = bursty_trace(12.0, 3);
    let mut c = cluster(4);
    c.rebalance.mode = RebalanceMode::Triggered;
    c.rebalance.remote_attach = true;
    let cfg = SimConfig::new(c, SystemKind::LoraServe);
    assert_shard_invariant(&trace, &cfg, "remote-attach");
}

#[test]
fn elastic_autoscale_drain_shard_invariant() {
    // grow from 1 server under burst, then drain back down: the
    // scale-up/drain re-places and re-routes must not observe the
    // shard count either
    let trace = uniform_trace(25.0, 4);
    let mut c = cluster(1);
    let acfg = AutoscaleConfig {
        min_servers: 1,
        max_servers: 5,
        decision_period: 10.0,
        cooldown: 15.0,
        provision_delay: 5.0,
        ..Default::default()
    };
    c.slo.timeout = 60.0;
    let cfg = SimConfig::new(c, SystemKind::LoraServe)
        .with_autoscale(acfg);
    assert_shard_invariant(&trace, &cfg, "elastic");
    // least-loaded routing drains differently (per-request re-route
    // with mini-flushes) — cover it too
    let mut c2 = cluster(1);
    c2.slo.timeout = 60.0;
    let cfg2 = SimConfig::new(c2, SystemKind::Toppings)
        .with_autoscale(acfg);
    assert_shard_invariant(&trace, &cfg2, "elastic-toppings");
}

#[test]
fn observed_exports_shard_invariant() {
    // with tracing + metrics + attribution on, the engine flushes
    // lanes inline (deterministic emission order through the shared
    // sink) — the report digest AND both export artifacts must be
    // byte-identical at any shard count
    let trace = uniform_trace(8.0, 5);
    let obs = loraserve::obs::ObsConfig {
        trace: true,
        metrics: true,
        attrib: true,
        ..Default::default()
    };
    let base = SimConfig::new(cluster(4), SystemKind::LoraServe)
        .with_obs(obs);
    let (mut seq_rep, seq_out) =
        sim::run_observed(&trace, &base.clone().with_shards(1));
    let want = seq_rep.to_json_string();
    for shards in [2usize, 8] {
        let (mut rep, out) =
            sim::run_observed(&trace, &base.clone().with_shards(shards));
        assert_eq!(
            want,
            rep.to_json_string(),
            "obs-on digest diverged at shards={shards}"
        );
        assert_eq!(
            seq_out.trace_json, out.trace_json,
            "trace export diverged at shards={shards}"
        );
        assert_eq!(
            seq_out.metrics_text, out.metrics_text,
            "metrics export diverged at shards={shards}"
        );
    }
}

#[test]
fn event_budget_aggregates_across_shards() {
    // the max_events backstop must count lane events too: a budget
    // small enough to be exhausted by deliveries alone has to fire at
    // every shard count
    let trace = uniform_trace(10.0, 6);
    for shards in [1usize, 4] {
        let cfg = SimConfig::new(cluster(4), SystemKind::LoraServe)
            .with_shards(shards);
        let mut tight = cfg.clone();
        tight.max_events = 100;
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                sim::run(&trace, &tight)
            }),
        );
        assert!(
            r.is_err(),
            "shards={shards}: 100-event budget did not trip"
        );
        // and a sane budget does not trip, with the same total count
        let rep = sim::run(&trace, &cfg);
        assert!(
            rep.events > trace.requests.len() as u64,
            "shards={shards}: lane events missing from the total"
        );
    }
}
