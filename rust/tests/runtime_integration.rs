//! Integration: the full AOT bridge. Loads the real artifacts
//! (`make artifacts`), compiles them on the PJRT CPU client, and checks
//! that greedy generation matches the goldens computed by the L2 jax
//! model — proving L1 (pallas) ⊂ L2 (jax) ⊂ L3 (rust) compose exactly.
//!
//! Tests are skipped (not failed) when artifacts/ hasn't been built.
//! The whole suite is gated on the `pjrt` feature (the offline build
//! image has no vendored `xla` crate).
#![cfg(feature = "pjrt")]

use loraserve::runtime::{argmax, ModelEngine};
use loraserve::util::json;

const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn engine() -> Option<ModelEngine> {
    if !std::path::Path::new(&format!("{DIR}/manifest.json")).exists() {
        eprintln!("artifacts/ missing; run `make artifacts` — skipping");
        return None;
    }
    Some(ModelEngine::load(DIR).expect("engine load"))
}

#[test]
fn generation_matches_python_goldens() {
    let Some(engine) = engine() else { return };
    let bank = ModelEngine::load_bank(DIR).expect("bank");
    let text = std::fs::read_to_string(format!("{DIR}/golden.json")).unwrap();
    let goldens = json::parse(&text).unwrap();
    let cases = goldens.as_arr().expect("golden array");
    assert!(!cases.is_empty());
    for (i, case) in cases.iter().enumerate() {
        let prompt: Vec<i32> = case
            .get("prompt")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as i32)
            .collect();
        let adapter_id = case.get("adapter").unwrap().as_usize().unwrap();
        let steps = case.get("steps").unwrap().as_usize().unwrap();
        let want: Vec<i32> = case
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as i32)
            .collect();
        let got = engine
            .generate(&prompt, &bank[adapter_id], steps)
            .expect("generate");
        assert_eq!(got, want, "golden case {i} (adapter {adapter_id})");
    }
}

#[test]
fn batched_prefill_matches_single() {
    // co-batching two requests with different adapters must yield the
    // same logits as running each alone (row independence through the
    // SGMV kernel's block routing).
    let Some(engine) = engine() else { return };
    let bank = ModelEngine::load_bank(DIR).unwrap();
    let p1: Vec<i32> = (1..20).collect();
    let p2: Vec<i32> = (5..12).collect();

    let stack_both = engine
        .stack_adapters(&[Some(&bank[0]), Some(&bank[4])])
        .unwrap();
    let shape = engine.pick_shape(2, 32).expect("batch-2-capable shape");
    let (batched, _) = engine
        .prefill(shape, &[p1.clone(), p2.clone()], &[0, 1], &stack_both)
        .unwrap();

    let s1 = engine.stack_adapters(&[Some(&bank[0])]).unwrap();
    let shape1 = engine.pick_shape(1, 32).unwrap();
    let (solo1, _) = engine.prefill(shape1, &[p1], &[0], &s1).unwrap();
    let s2 = engine.stack_adapters(&[Some(&bank[4])]).unwrap();
    let (solo2, _) = engine.prefill(shape1, &[p2], &[0], &s2).unwrap();

    for (a, b) in batched[0].iter().zip(solo1[0].iter()) {
        assert!((a - b).abs() < 1e-3, "row0: {a} vs {b}");
    }
    for (a, b) in batched[1].iter().zip(solo2[0].iter()) {
        assert!((a - b).abs() < 1e-3, "row1: {a} vs {b}");
    }
    // and the two rows genuinely used different adapters
    assert_ne!(argmax(&batched[0]), {
        // (may coincide; check raw logits differ instead)
        let d: f32 = batched[0]
            .iter()
            .zip(batched[1].iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(d > 1e-3, "rows identical");
        i32::MIN
    });
}

#[test]
fn adapter_swap_changes_logits() {
    let Some(engine) = engine() else { return };
    let bank = ModelEngine::load_bank(DIR).unwrap();
    let prompt: Vec<i32> = (10..25).collect();
    let shape = engine.pick_shape(1, 32).unwrap();
    let sa = engine.stack_adapters(&[Some(&bank[0])]).unwrap();
    let sb = engine.stack_adapters(&[Some(&bank[4])]).unwrap();
    let (la, _) = engine
        .prefill(shape, &[prompt.clone()], &[0], &sa)
        .unwrap();
    let (lb, _) = engine.prefill(shape, &[prompt], &[0], &sb).unwrap();
    let diff: f32 = la[0]
        .iter()
        .zip(lb[0].iter())
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 1e-3, "adapters 0 and 4 gave identical logits");
}

#[test]
fn engine_reports_shapes() {
    let Some(engine) = engine() else { return };
    assert!(!engine.prefill_shapes().is_empty());
    assert!(!engine.decode_batches().is_empty());
    // every prefill batch has a decode twin (ABI requirement)
    for (b, _) in engine.prefill_shapes() {
        assert!(
            engine.decode_batches().contains(&b),
            "no decode artifact for batch {b}"
        );
    }
    let bank = ModelEngine::load_bank(DIR).unwrap();
    assert_eq!(bank.len(), engine.manifest.bank_ranks.len());
    for (a, &r) in bank.iter().zip(engine.manifest.bank_ranks.iter()) {
        assert_eq!(a.rank, r);
    }
}
