//! Drift-reactive rebalancing invariants and the PR's acceptance
//! criterion.
//!
//! * On a DriftUp/DriftDown production-shape trace, `--rebalance-mode
//!   triggered` migrates strictly fewer bytes than the open-loop
//!   periodic timer at equal-or-better tail TTFT.
//! * `--rebalance-mode periodic` with default knobs is the pre-trigger
//!   engine: the trigger never runs, nothing is served remotely, and
//!   the run digest is unaffected by the (inert) rebalance config.
//! * Triggered runs are deterministic per seed; a stable trace fires
//!   zero triggered rebalances and a step-change trace fires a single
//!   bounded burst (the fine-grained hysteresis properties live in
//!   `sim::rebalance`'s unit tests — these cover the engine loop).

use loraserve::config::{
    ClusterConfig, RebalanceConfig, RebalanceMode,
};
use loraserve::figures::drift::{drift_rebalance, drift_trace};
use loraserve::sim::{self, SimConfig, SimReport, SystemKind};
use loraserve::trace::azure::{self, AzureConfig};
use loraserve::trace::{LengthModel, Trace};

fn cluster(rebalance: RebalanceConfig) -> ClusterConfig {
    ClusterConfig {
        n_servers: 4,
        rebalance_period: 60.0,
        rebalance,
        ..Default::default()
    }
}

fn run_mode(trace: &Trace, rebalance: RebalanceConfig) -> SimReport {
    sim::run(
        trace,
        &SimConfig::new(cluster(rebalance), SystemKind::LoraServe),
    )
}

/// Acceptance: under genuine drift, the trigger + incremental planner
/// move strictly fewer bytes than the open-loop timer, at
/// equal-or-better p99 TTFT (small tolerance for sampling noise).
#[test]
fn triggered_migrates_fewer_bytes_at_no_worse_p99() {
    let trace = drift_trace(40, 10.0, 600.0, 3);
    let mut per = run_mode(
        &trace,
        drift_rebalance(RebalanceMode::Periodic, false),
    );
    let mut tri = run_mode(
        &trace,
        drift_rebalance(RebalanceMode::Triggered, false),
    );
    for (rep, label) in [(&per, "periodic"), (&tri, "triggered")] {
        assert_eq!(
            rep.completed + rep.timeouts,
            trace.requests.len() as u64,
            "{label}: requests lost"
        );
    }
    // the open-loop timer kept re-placing; the trigger was selective
    assert!(per.rebalances >= 4, "periodic: {}", per.rebalances);
    assert_eq!(per.triggered_rebalances, 0);
    assert!(
        tri.migration_bytes < per.migration_bytes,
        "triggered must migrate strictly fewer bytes: {} !< {}",
        tri.migration_bytes,
        per.migration_bytes
    );
    let (p99_per, p99_tri) = (per.ttft.p99(), tri.ttft.p99());
    assert!(
        p99_tri <= p99_per * 1.05,
        "triggered p99 TTFT {p99_tri} worse than periodic {p99_per}"
    );
}

/// Remote attach serves pool misses out of the peer's HBM instead of
/// fetching a copy: under hybrid mode every wholesale re-place moves
/// some homes, so the subsequent arrivals at not-yet-resident homes
/// must be remote-served (with remote attach off they would have
/// started RDMA fetches instead). Triggered+remote still migrates
/// strictly fewer bytes than the open-loop timer.
#[test]
fn remote_attach_serves_remotely_without_moving_bytes() {
    let trace = drift_trace(40, 10.0, 600.0, 3);
    let hybrid_ra = run_mode(
        &trace,
        drift_rebalance(RebalanceMode::Hybrid, true),
    );
    assert_eq!(
        hybrid_ra.completed + hybrid_ra.timeouts,
        trace.requests.len() as u64,
        "remote attach lost requests"
    );
    assert!(
        hybrid_ra.remote_served > 0,
        "misses after a wholesale re-place must be served remotely"
    );
    let per = run_mode(
        &trace,
        drift_rebalance(RebalanceMode::Periodic, false),
    );
    let tri_ra = run_mode(
        &trace,
        drift_rebalance(RebalanceMode::Triggered, true),
    );
    assert_eq!(
        tri_ra.completed + tri_ra.timeouts,
        trace.requests.len() as u64
    );
    assert!(
        tri_ra.migration_bytes < per.migration_bytes,
        "triggered+remote migrated more than periodic: {} !< {}",
        tri_ra.migration_bytes,
        per.migration_bytes
    );
}

/// Periodic mode with default knobs is the pre-trigger engine: the
/// trigger never evaluates, nothing is planned incrementally or served
/// remotely, and the digest is identical whether the (inert) default
/// rebalance config is spelled out or not — plus deterministic across
/// runs, which is what the CI gate byte-compares.
#[test]
fn periodic_default_is_inert_and_deterministic() {
    let trace = drift_trace(30, 8.0, 300.0, 5);
    let mut a = sim::run(
        &trace,
        &SimConfig::new(
            ClusterConfig {
                n_servers: 4,
                rebalance_period: 60.0,
                ..Default::default()
            },
            SystemKind::LoraServe,
        ),
    );
    let mut b = run_mode(&trace, RebalanceConfig::default());
    assert_eq!(a.trigger_checks, 0);
    assert_eq!(a.triggered_rebalances, 0);
    assert_eq!(a.incremental_moves, 0);
    assert_eq!(a.remote_served, 0);
    assert_eq!(
        a.to_json_string(),
        b.to_json_string(),
        "spelling out the default rebalance config must not perturb \
         the run"
    );
    // rebalance timestamps are recorded for the warmup derivation
    assert_eq!(a.rebalance_times.len() as u64, a.rebalances);
    assert!(a.rebalances >= 2);
}

/// Triggered runs are deterministic per (trace, config, seed) — the
/// trigger, the incremental planner, and remote attach introduce no
/// randomness.
#[test]
fn triggered_runs_are_deterministic() {
    let trace = drift_trace(30, 8.0, 300.0, 7);
    for remote in [false, true] {
        let mut r1 = run_mode(
            &trace,
            drift_rebalance(RebalanceMode::Triggered, remote),
        );
        let mut r2 = run_mode(
            &trace,
            drift_rebalance(RebalanceMode::Triggered, remote),
        );
        assert_eq!(
            r1.to_json_string(),
            r2.to_json_string(),
            "remote={remote}: non-deterministic triggered run"
        );
        assert_eq!(r1.makespan.to_bits(), r2.makespan.to_bits());
    }
}

/// A stable (non-drifting) trace never crosses the default imbalance
/// threshold: the trigger evaluates every check period and fires
/// nothing.
#[test]
fn stable_trace_fires_zero_triggered_rebalances() {
    // uniform rank popularity, Poisson arrivals, flat rate: the
    // projected per-server imbalance stays far below the 1.5 default
    let trace = azure::generate(&AzureConfig {
        rps: 16.0,
        duration: 300.0,
        seed: 11,
        lengths: LengthModel::fixed(256, 8),
        ..Default::default()
    });
    let rep =
        run_mode(&trace, RebalanceConfig {
            mode: RebalanceMode::Triggered,
            ..Default::default()
        });
    assert!(rep.trigger_checks >= 10, "{}", rep.trigger_checks);
    assert_eq!(
        rep.triggered_rebalances, 0,
        "stable trace must not trigger (checks: {})",
        rep.trigger_checks
    );
    assert_eq!(rep.rebalances, 0);
    assert_eq!(rep.migration_bytes, 0);
}

/// A step change — traffic collapsing onto a handful of adapters
/// mid-trace — fires a bounded burst: at least one triggered
/// rebalance, and nowhere near one per check (the hysteresis +
/// min-interval guards; the exact one-fire-per-episode property is
/// unit-tested in `sim::rebalance`).
#[test]
fn step_change_fires_a_bounded_burst() {
    // phase 1: uniform over 25 adapters; phase 2: everything on
    // adapters {0, 5} — far more demand than their homes expect
    let base = azure::generate(&AzureConfig {
        rps: 14.0,
        duration: 420.0,
        seed: 13,
        lengths: LengthModel::fixed(256, 8),
        ..Default::default()
    });
    let mut requests = base.requests.clone();
    for r in requests.iter_mut() {
        if r.arrival >= 150.0 {
            r.adapter = if r.adapter % 2 == 0 { 0 } else { 5 };
        }
    }
    let trace = Trace::new("step-change", base.adapters, requests);
    let rep = run_mode(
        &trace,
        RebalanceConfig {
            mode: RebalanceMode::Triggered,
            ..Default::default()
        },
    );
    assert!(
        rep.triggered_rebalances >= 1,
        "the step must fire the trigger (checks: {})",
        rep.trigger_checks
    );
    assert!(
        rep.triggered_rebalances <= rep.trigger_checks / 3,
        "trigger thrashing: {} fires over {} checks",
        rep.triggered_rebalances,
        rep.trigger_checks
    );
    assert_eq!(
        rep.completed + rep.timeouts,
        trace.requests.len() as u64
    );
}
