//! Scheduler-layer tests: engine/spec parity (the refactor seam),
//! batch-policy invariants (starvation bound, homogeneity), the
//! rank-aware scheduling effect the `sched` ablation reports, and the
//! `sched` figure smoke-run.

use loraserve::config::{
    BatchPolicyKind, ClassSelect, ClusterConfig, DecodePolicyKind,
    RebalanceConfig, SloFeedbackConfig,
};
use loraserve::figures::sched::{sched_decode_table, sched_table};
use loraserve::sim::{
    self, run_spec, LoadSignal, PlacementPolicy, PoolMode,
    RoutingPolicy, SimConfig, SystemKind, SystemSpec,
};
use loraserve::trace::azure::{self, AzureConfig};
use loraserve::trace::{LengthModel, Trace};

fn cluster(n: usize) -> ClusterConfig {
    ClusterConfig {
        n_servers: n,
        rebalance_period: 20.0,
        ..Default::default()
    }
}

/// Mixed ranks (uniform over the five classes), short outputs so
/// prefill iterations dominate the iteration mix.
fn mixed_trace(rps: f64, seed: u64, duration: f64) -> Trace {
    azure::generate(&AzureConfig {
        rps,
        duration,
        seed,
        lengths: LengthModel::fixed(512, 2),
        ..Default::default()
    })
}

/// The four §V-D systems, composed *by hand* from the engine's policy
/// vocabulary — independently of `SystemKind::spec`, so the parity
/// test below certifies the composition seam rather than tautology.
fn hand_composed(kind: SystemKind) -> SystemSpec {
    let base = SystemSpec {
        label: kind.label().to_string(),
        placement: PlacementPolicy::Contiguous,
        routing: RoutingPolicy::Table,
        pool: PoolMode::Distributed,
        batch: BatchPolicyKind::Fifo,
        decode: DecodePolicyKind::Unified,
        periodic_rebalance: false,
        empirical_oppoints: false,
        rank_agnostic: false,
        last_value_demand: false,
        load_signal: LoadSignal::ServiceSeconds,
        rank_blind_cost: false,
        slo: SloFeedbackConfig::default(),
        rebalance: RebalanceConfig::default(),
        scenario: Default::default(),
    };
    match kind {
        SystemKind::LoraServe => SystemSpec {
            placement: PlacementPolicy::LoraServe {
                skip_permutation: false,
            },
            periodic_rebalance: true,
            empirical_oppoints: true,
            ..base
        },
        SystemKind::SLoraRandom => SystemSpec {
            placement: PlacementPolicy::Random,
            ..base
        },
        SystemKind::SLoraContiguous => base,
        SystemKind::Toppings => SystemSpec {
            placement: PlacementPolicy::ReplicateAll,
            routing: RoutingPolicy::LeastLoaded,
            pool: PoolMode::Replicated,
            load_signal: LoadSignal::RequestCount,
            rank_blind_cost: true,
            ..base
        },
    }
}

/// Engine parity: under `BatchPolicy::Fifo` the refactored engine must
/// produce a bit-identical seeded `SimReport` — completions, latency
/// samples, fetches, migration bytes — for all four systems, whether
/// the system arrives as a canned `SystemKind` or a hand-composed
/// `SystemSpec`.
#[test]
fn fifo_engine_parity_all_systems() {
    let trace = mixed_trace(10.0, 2, 240.0);
    for kind in SystemKind::all() {
        let cfg = SimConfig::new(cluster(4), kind);
        assert_eq!(cfg.batch, BatchPolicyKind::Fifo, "default policy");
        let r1 = sim::run(&trace, &cfg);
        let r2 = run_spec(&trace, &cfg, &hand_composed(kind));
        // and a second canned run for plain determinism
        let r3 = sim::run(&trace, &cfg);
        for (a, b) in [(&r1, &r2), (&r1, &r3)] {
            assert_eq!(a.completed, b.completed, "{}", kind.label());
            assert_eq!(a.timeouts, b.timeouts, "{}", kind.label());
            assert_eq!(a.fetches, b.fetches, "{}", kind.label());
            assert_eq!(a.fetch_bytes, b.fetch_bytes, "{}", kind.label());
            assert_eq!(
                a.migration_bytes,
                b.migration_bytes,
                "{}",
                kind.label()
            );
            assert_eq!(a.rebalances, b.rebalances, "{}", kind.label());
            assert_eq!(
                a.makespan.to_bits(),
                b.makespan.to_bits(),
                "{}",
                kind.label()
            );
            assert_eq!(a.ttft.values(), b.ttft.values(), "{}", kind.label());
            assert_eq!(a.e2e.values(), b.e2e.values(), "{}", kind.label());
            assert_eq!(a.tbt.values(), b.tbt.values(), "{}", kind.label());
            assert_eq!(
                a.per_server_busy,
                b.per_server_busy,
                "{}",
                kind.label()
            );
            assert_eq!(a.gpu_loads, b.gpu_loads, "{}", kind.label());
            assert_eq!(a.iters, b.iters, "{}", kind.label());
            assert_eq!(
                a.iters_highrank,
                b.iters_highrank,
                "{}",
                kind.label()
            );
            assert_eq!(a.system, b.system, "{}", kind.label());
        }
        assert!(r1.iters > 0);
    }
}

/// The acceptance check behind the scheduler half of the design space:
/// under rank-agnostic (random) placement, rank-bucketed admission
/// keeps prefill batches homogeneous and shrinks the share of
/// iterations paying the ≥64-rank padding tax.
#[test]
fn rank_bucketed_reduces_highrank_share_under_random_placement() {
    let trace = mixed_trace(24.0, 4, 300.0);
    let fifo =
        sim::run(&trace, &SimConfig::new(cluster(2), SystemKind::SLoraRandom));
    let bucketed = sim::run(
        &trace,
        &SimConfig::new(cluster(2), SystemKind::SLoraRandom)
            .with_params(|p| {
                p.batch(BatchPolicyKind::RankBucketed {
                    max_wait_iters: 8,
                    select: ClassSelect::LargestQueue,
                })
            }),
    );
    // structural: one rank class per prefill — no mixed batches, no
    // padded prefill tokens at all
    assert_eq!(bucketed.mixed_prefill_iters, 0);
    assert_eq!(bucketed.pad_rank_tokens, 0);
    assert!(
        fifo.mixed_prefill_iters > 0,
        "trace too light to ever mix under fifo"
    );
    assert!(fifo.pad_rank_tokens > 0);
    // behavioral: the high-rank iteration share drops
    assert!(
        bucketed.highrank_iter_share() < fifo.highrank_iter_share(),
        "bucketed {} !< fifo {}",
        bucketed.highrank_iter_share(),
        fifo.highrank_iter_share()
    );
    // no request is lost to the scheduling change
    assert_eq!(
        bucketed.completed + bucketed.timeouts,
        trace.requests.len() as u64
    );
    assert_eq!(bucketed.batch_policy, "rank-bucketed:8");
}

/// RankCap lowers the padding tax without reordering across classes:
/// padded prefill tokens strictly shrink vs FIFO on a mixed trace.
#[test]
fn rank_cap_shrinks_padding_tax() {
    let trace = mixed_trace(24.0, 6, 240.0);
    let fifo =
        sim::run(&trace, &SimConfig::new(cluster(2), SystemKind::SLoraRandom));
    let capped = sim::run(
        &trace,
        &SimConfig::new(cluster(2), SystemKind::SLoraRandom)
            .with_params(|p| p.batch(BatchPolicyKind::RankCap { factor: 2 })),
    );
    assert!(fifo.pad_rank_tokens > 0);
    assert!(
        capped.pad_rank_tokens < fifo.pad_rank_tokens,
        "capped {} !< fifo {}",
        capped.pad_rank_tokens,
        fifo.pad_rank_tokens
    );
    assert_eq!(
        capped.completed + capped.timeouts,
        trace.requests.len() as u64
    );
}

/// Property: RankBucketed's bounded-wait guard — no request, once at
/// the head of the queue, is passed over more than `max_wait_iters`
/// admitting prefill iterations, under adversarial arrivals and
/// capacities.
#[test]
fn rank_bucketed_starvation_bound_property() {
    use loraserve::sim::server::{BatchPolicy, RankBucketed, SimReq};
    use loraserve::util::rng::Pcg32;
    use loraserve::workload::Request;
    use std::collections::{BTreeMap, VecDeque};
    let bound = 3u32;
    for seed in 0..6u64 {
        let mut rng = Pcg32::new(100 + seed);
        let mut pol = RankBucketed::new(bound);
        let mut queue: VecDeque<SimReq> = VecDeque::new();
        let mut next_id = 0u64;
        let mut waits: BTreeMap<u64, u32> = BTreeMap::new();
        for _iter in 0..500 {
            for _ in 0..rng.below(4) {
                let rank = [8u32, 16, 64, 128][rng.below(4) as usize];
                queue.push_back(SimReq {
                    req: Request {
                        id: next_id,
                        adapter: 0,
                        prompt_len: 64 + rng.below(400) as u32,
                        output_len: 1,
                        arrival: 0.0,
                    },
                    rank,
                    adapter_bytes: 1 << 20,
                    est: 0.1,
                    remote: false,
                    uid: 0,
                });
                next_id += 1;
            }
            let front = queue.front().map(|r| r.req.id);
            let slots = 1 + rng.below(6) as usize;
            let batch = pol.admit(&mut queue, slots, 2048);
            let Some(f) = front else { continue };
            if batch.iter().any(|r| r.req.id == f) {
                waits.remove(&f);
            } else if !batch.is_empty() {
                let w = waits.entry(f).or_insert(0);
                *w += 1;
                assert!(
                    *w <= bound,
                    "seed {seed}: request {f} passed over {w} times at \
                     the head (bound {bound})"
                );
            }
        }
    }
}

/// The `sched` figure's harness renders a non-empty table on a tiny
/// trace (the CI smoke-run for the ablation).
#[test]
fn sched_figure_smoke_run() {
    let trace = mixed_trace(4.0, 1, 60.0);
    let table = sched_table(&trace, &cluster(2));
    assert_eq!(
        table.rows.len(),
        SystemKind::all().len() * 4,
        "one row per system × policy"
    );
    for row in &table.rows {
        assert!(!row.is_empty());
        for cell in row {
            assert!(!cell.is_empty(), "empty cell in {row:?}");
        }
    }
    let md = table.to_markdown();
    assert!(md.contains("fifo"));
    assert!(md.contains("rank-bucketed"));
    assert!(md.contains("rank-bucketed-cost"));
    assert!(md.contains("rank-cap"));
    assert!(md.contains("loraserve") && md.contains("toppings"));
}

/// The decode half of the ablation renders the full prefill × decode
/// grid on a tiny trace.
#[test]
fn sched_decode_figure_smoke_run() {
    let trace =
        loraserve::figures::sched::skewed_decode_trace(4.0, 1, 60.0);
    let table = sched_decode_table(&trace, &cluster(2));
    assert_eq!(table.rows.len(), 2 * 3, "prefill × decode grid");
    for row in &table.rows {
        for cell in row {
            assert!(!cell.is_empty(), "empty cell in {row:?}");
        }
    }
    let md = table.to_markdown();
    assert!(md.contains("unified"));
    assert!(md.contains("rank-partitioned"));
    assert!(md.contains("class-subbatch"));
}
