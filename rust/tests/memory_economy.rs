//! The unified HBM economy contract (pool/hbm.rs):
//!
//! 1. **Unbounded bit-parity** — the default config (`hbm_pages = 0`)
//!    must leave every system's report digest byte-identical to the
//!    pre-refactor code: no `hbm` block, no `fetch_stall` key, and the
//!    same bytes at any shard count. An *ample* bounded budget must
//!    reproduce the unbounded digest exactly, modulo the appended
//!    `hbm` block (zero evictions).
//! 2. **Sharded determinism under pressure** — a constrained budget
//!    with real eviction churn still digests byte-identically at
//!    shards 1/2/8 (evictions drain at epoch barriers in lane order).
//! 3. **Policy quality** — on a long-context × many-adapter trace at a
//!    constrained budget, rank-weighted or slo-aware eviction beats
//!    plain LRU on p99 TTFT.
//! 4. **Memory-pressure trigger** — OR-ing the occupancy signal into
//!    the rebalance trigger reduces fleet fetch-stall seconds vs a
//!    pressure-blind trigger on a drifting workload.

use loraserve::config::{ClusterConfig, RebalanceMode};
use loraserve::figures::drift::drift_trace;
use loraserve::figures::memory::memory_trace;
use loraserve::pool::hbm::EvictPolicy;
use loraserve::sim::{self, SimConfig, SimReport, SystemKind};
use loraserve::trace::azure::{self, AzureConfig};
use loraserve::trace::{LengthModel, Trace};

/// Small default-shape trace: working sets stay far under the legacy
/// byte budget, so the ample-budget parity comparison below is not
/// confounded by legacy byte-LRU evictions.
fn small_trace(seed: u64) -> Trace {
    azure::generate(&AzureConfig {
        rps: 10.0,
        duration: 120.0,
        seed,
        lengths: LengthModel::fixed(256, 16),
        ..Default::default()
    })
}

fn cluster(pages: usize, policy: EvictPolicy) -> ClusterConfig {
    let mut c = ClusterConfig {
        n_servers: 4,
        ..Default::default()
    };
    c.server.hbm_pages = pages;
    c.server.evict_policy = policy;
    c
}

fn digest(
    trace: &Trace,
    cfg: &SimConfig,
    shards: usize,
) -> (String, SimReport) {
    let mut rep = sim::run(trace, &cfg.clone().with_shards(shards));
    let d = rep.to_json_string();
    (d, rep)
}

#[test]
fn unbounded_default_digest_has_no_hbm_and_is_shard_invariant() {
    let trace = small_trace(1);
    for system in SystemKind::all() {
        let cfg = SimConfig::new(
            cluster(0, EvictPolicy::Lru),
            system,
        );
        let (seq, rep) = digest(&trace, &cfg, 1);
        assert!(rep.events > 0, "{}: no events", system.label());
        // the pre-refactor digest shape: the hbm block and the stall
        // scalar must be absent (bit-parity with PR 9 reports)
        assert!(
            !seq.contains("\"hbm\""),
            "{}: unbounded digest grew an hbm block",
            system.label()
        );
        assert!(!seq.contains("fetch_stall"), "{}", system.label());
        let (sharded, _) = digest(&trace, &cfg, 8);
        assert_eq!(
            seq,
            sharded,
            "{}: unbounded digest diverged at shards=8",
            system.label()
        );
    }
}

#[test]
fn ample_budget_matches_unbounded_modulo_hbm_block() {
    // a budget big enough that nothing is ever squeezed: identical
    // arithmetic to the unbounded pool on every code path, so the
    // digest may differ only by the appended hbm block
    let trace = small_trace(2);
    let unb = SimConfig::new(
        cluster(0, EvictPolicy::Lru),
        SystemKind::LoraServe,
    );
    let ample = SimConfig::new(
        cluster(1 << 20, EvictPolicy::Lru),
        SystemKind::LoraServe,
    );
    for shards in [1usize, 8] {
        let (u, _) = digest(&trace, &unb, shards);
        let (b, rep) = digest(&trace, &ample, shards);
        assert!(
            b.starts_with(&u[..u.len() - 1]),
            "shards={shards}: ample-budget digest diverged before \
             the hbm block\nunbounded: {u}\nbounded:   {b}"
        );
        assert!(b.contains("\"hbm\":{"), "shards={shards}");
        let h = rep.hbm.expect("bounded run must report hbm stats");
        assert_eq!(h.evictions, 0, "ample budget must not evict");
        assert_eq!(h.total_pages, 1 << 20);
        assert!(h.peak_pages > 0, "pages were never accounted");
    }
}

#[test]
fn constrained_budget_is_shard_invariant_under_eviction_churn() {
    let trace = memory_trace(48, 8.0, 240.0, 3);
    let cfg = SimConfig::new(
        cluster(512, EvictPolicy::RankWeighted),
        SystemKind::LoraServe,
    );
    let (seq, rep) = digest(&trace, &cfg, 1);
    let h = rep.hbm.expect("bounded run must report hbm stats");
    assert!(h.evictions > 0, "no pressure: the gate is vacuous");
    assert!(h.evicted_bytes > 0);
    assert!(
        h.peak_kv_pages > 0,
        "KV footprint never entered the pool"
    );
    for shards in [2usize, 8] {
        let (d, _) = digest(&trace, &cfg, shards);
        assert_eq!(
            seq, d,
            "pressure digest diverged at shards={shards}"
        );
    }
}

#[test]
fn smarter_eviction_beats_lru_on_tail_ttft() {
    // long-context × many-adapter at a budget tight enough that KV and
    // adapter residency fight for pages the whole run; every request
    // completes (no timeout censoring), so p99 TTFT reflects the full
    // queueing + paging tail of each policy
    let trace = memory_trace(48, 10.0, 480.0, 0);
    let run_policy = |policy: EvictPolicy| -> (f64, u64) {
        let mut c = cluster(384, policy);
        c.slo.timeout = 1e9;
        let mut rep = sim::run(
            &trace,
            &SimConfig::new(c, SystemKind::LoraServe),
        );
        assert_eq!(rep.timeouts, 0, "{}: censored tail", policy.label());
        let h = rep.hbm.expect("bounded run must report hbm stats");
        (rep.ttft.p99(), h.evictions)
    };
    let (lru, lru_ev) = run_policy(EvictPolicy::Lru);
    let (rw, _) = run_policy(EvictPolicy::RankWeighted);
    let (slo, _) = run_policy(EvictPolicy::SloAware);
    assert!(lru_ev > 0, "no eviction churn: comparison is vacuous");
    assert!(
        rw < lru || slo < lru,
        "neither rank-weighted ({rw:.3}s) nor slo-aware ({slo:.3}s) \
         beat lru ({lru:.3}s) on p99 TTFT at equal budget"
    );
}

#[test]
fn memory_trigger_reduces_fetch_stall_vs_pressure_blind() {
    // drifting demand (DriftUp rank-8 vs DriftDown rank-64) at a
    // constrained budget: eviction churn drops pool copies, so a
    // placement that no longer tracks demand pays for it in fetch
    // stalls. The pressure-blind arm never rebalances (imbalance
    // threshold unreachable, every other signal off); the memory arm
    // differs ONLY in the occupancy signal. Idle dips between bursts
    // shrink the KV footprint below the hot mark and re-arm the
    // latch, so the trigger tracks the drift instead of firing once.
    let trace = drift_trace(40, 12.0, 480.0, 4);
    let run_arm = |memory_signal: bool| -> SimReport {
        let mut c = cluster(768, EvictPolicy::Lru);
        c.rebalance.mode = RebalanceMode::Triggered;
        c.rebalance.imbalance_threshold = 1e9;
        c.rebalance.memory_signal = memory_signal;
        c.rebalance.occupancy_hot = 0.5;
        sim::run(&trace, &SimConfig::new(c, SystemKind::LoraServe))
    };
    let blind = run_arm(false);
    let aware = run_arm(true);
    assert_eq!(
        blind.rebalances, 0,
        "pressure-blind arm must never rebalance"
    );
    assert!(
        aware.triggered_rebalances > 0,
        "occupancy signal never fired"
    );
    assert!(
        blind.fetch_stall_s > 0.0,
        "no fetch stalls without rebalancing: comparison is vacuous"
    );
    assert!(
        aware.fetch_stall_s < blind.fetch_stall_s,
        "memory-pressure triggering did not reduce fetch stall: \
         aware {:.3}s vs blind {:.3}s",
        aware.fetch_stall_s,
        blind.fetch_stall_s
    );
}
