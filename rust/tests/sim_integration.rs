//! Cross-module integration tests: coordinator + placement + pool +
//! simulator end-to-end, plus system-level invariants the paper's
//! claims rest on. (Runtime/PJRT integration lives in
//! runtime_integration.rs.)

use loraserve::config::ClusterConfig;
use loraserve::sim::{run, LoraServeOpts, SimConfig, SystemKind};
use loraserve::trace::azure::{self, AzureConfig, RankPopularity};
use loraserve::trace::production::{self, ProductionConfig};
use loraserve::trace::{LengthModel, Trace};

fn cluster(n: usize) -> ClusterConfig {
    ClusterConfig {
        n_servers: n,
        ..Default::default()
    }
}

fn shifting_trace(rps: f64, seed: u64) -> Trace {
    azure::generate(&AzureConfig {
        popularity: RankPopularity::ShiftingSkew,
        rps,
        duration: 600.0,
        seed,
        ..Default::default()
    })
}

#[test]
fn conservation_every_request_accounted() {
    // completed + timeouts == offered, for every system, on a drifting
    // trace with rebalances and fetches in flight
    let trace = shifting_trace(12.0, 3);
    for system in SystemKind::all() {
        let rep = run(&trace, &SimConfig::new(cluster(4), system));
        assert_eq!(
            rep.completed + rep.timeouts,
            trace.requests.len() as u64,
            "{}",
            system.label()
        );
    }
}

#[test]
fn loraserve_beats_static_baselines_under_drift() {
    // the paper's core qualitative claim (Fig 19, shifting skew):
    // dynamic rank-aware placement sustains load that static
    // placements cannot
    let trace = shifting_trace(18.0, 1);
    let mut ls = run(
        &trace,
        &SimConfig::new(cluster(4), SystemKind::LoraServe)
            .with_warmup(120.0),
    );
    let mut rnd = run(
        &trace,
        &SimConfig::new(cluster(4), SystemKind::SLoraRandom)
            .with_warmup(120.0),
    );
    let ls_p95 = ls.ttft_p95();
    let rnd_p95 = rnd.ttft_p95();
    assert!(
        ls_p95 < rnd_p95 || rnd.timeouts > ls.timeouts,
        "loraserve p95 {ls_p95} vs random {rnd_p95} \
         (timeouts {} vs {})",
        ls.timeouts,
        rnd.timeouts
    );
}

#[test]
fn loraserve_memory_footprint_below_replication() {
    // Fig 18 bottom: the distributed pool keeps far fewer adapters
    // resident than Toppings' full replication
    let trace = production::generate(&ProductionConfig {
        n_adapters: 100,
        n_requests: 8000,
        duration: 500.0,
        seed: 0,
        ..Default::default()
    });
    let ls = run(
        &trace,
        &SimConfig::new(cluster(4), SystemKind::LoraServe),
    );
    let tp = run(
        &trace,
        &SimConfig::new(cluster(4), SystemKind::Toppings),
    );
    let ls_max = *ls.per_server_max_adapters.iter().max().unwrap();
    let tp_max = *tp.per_server_max_adapters.iter().max().unwrap();
    assert_eq!(tp_max, 100);
    assert!(ls_max < 70, "loraserve resident {ls_max}");
}

#[test]
fn rank_aware_beats_rank_agnostic_ablation() {
    // A4: with operating points flattened, placement balances load but
    // mixes ranks; the rank-aware variant must not be worse
    let trace = shifting_trace(18.0, 5);
    let mut aware = SimConfig::new(cluster(4), SystemKind::LoraServe);
    aware.warmup = 120.0;
    let mut agnostic = aware.clone();
    agnostic.opts = LoraServeOpts {
        rank_agnostic: true,
        ..Default::default()
    };
    let mut rep_aware = run(&trace, &aware);
    let mut rep_agnostic = run(&trace, &agnostic);
    let a = rep_aware.ttft_p95();
    let b = rep_agnostic.ttft_p95();
    assert!(
        a <= b * 1.5 + 0.2,
        "rank-aware {a} much worse than agnostic {b}"
    );
}

#[test]
fn higher_load_never_lowers_latency() {
    // sanity on the whole stack: p95 TTFT is (weakly) monotone in RPS
    let base = shifting_trace(8.0, 7);
    let mut last = 0.0;
    for rps in [6.0, 12.0, 24.0] {
        let t = base.scale_to_rps(rps);
        let mut rep = run(
            &t,
            &SimConfig::new(cluster(2), SystemKind::SLoraContiguous),
        );
        let p95 = rep.ttft_p95();
        assert!(
            p95 >= last * 0.5,
            "p95 collapsed from {last} to {p95} at {rps} rps"
        );
        last = p95;
    }
    assert!(last > 0.2, "heaviest load too fast: {last}");
}

#[test]
fn fixed_shape_workload_matches_fig6_shape() {
    // single-rank 512/128 at 4 RPS on one server: small ranks fine,
    // rank 128 violates — the crossover the whole paper hangs on
    let mk = |rank: u32| -> Trace {
        let mut cfgt = AzureConfig {
            adapters_per_rank: 1,
            rps: 4.0,
            duration: 600.0,
            lengths: LengthModel::fixed(512, 128),
            ..Default::default()
        };
        cfgt.seed = 11;
        let mut t = azure::generate(&cfgt);
        let target = t
            .adapters
            .iter()
            .find(|a| a.rank == rank)
            .unwrap()
            .id;
        for r in t.requests.iter_mut() {
            r.adapter = target;
        }
        t
    };
    let mut small = run(
        &mk(8),
        &SimConfig::new(cluster(1), SystemKind::SLoraContiguous),
    );
    let mut big = run(
        &mk(128),
        &SimConfig::new(cluster(1), SystemKind::SLoraContiguous),
    );
    assert!(small.ttft_p95() < 5.0, "rank8 p95 {}", small.ttft_p95());
    assert!(big.ttft_p95() > 20.0, "rank128 p95 {}", big.ttft_p95());
}

#[test]
fn deterministic_end_to_end() {
    let trace = shifting_trace(14.0, 9);
    let cfg = SimConfig::new(cluster(4), SystemKind::LoraServe);
    let mut a = run(&trace, &cfg);
    let mut b = run(&trace, &cfg);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.timeouts, b.timeouts);
    assert_eq!(a.ttft_p95(), b.ttft_p95());
    assert_eq!(a.tbt_p95(), b.tbt_p95());
    assert_eq!(a.fetches, b.fetches);
    assert_eq!(a.migration_bytes, b.migration_bytes);
}

#[test]
fn weak_scaling_carries_proportional_load() {
    // Fig 21's shape: 2x servers sustain ~2x the traffic
    let mk = |per_rank: usize, rps: f64, seed: u64| {
        azure::generate(&AzureConfig {
            adapters_per_rank: per_rank,
            rps,
            duration: 500.0,
            seed,
            ..Default::default()
        })
    };
    let mut small = run(
        &mk(5, 10.0, 13),
        &SimConfig::new(cluster(2), SystemKind::LoraServe)
            .with_warmup(120.0),
    );
    let mut big = run(
        &mk(10, 20.0, 13),
        &SimConfig::new(cluster(4), SystemKind::LoraServe)
            .with_warmup(120.0),
    );
    assert!(small.meets_slo(10.0), "2srv@10rps violates SLO");
    assert!(big.meets_slo(10.0), "4srv@20rps violates SLO");
}
