//! The indexed control plane must be invisible in results: the
//! argmin-tree router is a drop-in for the linear least-loaded scan
//! (lowest-index tie-break included), and the incremental coordinator
//! (dirty-tracked router loads, ring-buffer demand projections,
//! delta-maintained utilization) produces byte-identical report
//! digests at any shard count for every canned system. The in-engine
//! `debug_assert` parity nets (stale-load detection, util-cache vs
//! full recompute) also run live inside these simulations, since
//! integration tests build with debug assertions on.

use loraserve::config::{ClusterConfig, RebalanceMode};
use loraserve::sim::{self, SimConfig, SystemKind};
use loraserve::trace::azure::{self, AzureConfig, RankPopularity};
use loraserve::trace::{LengthModel, Trace};
use loraserve::util::argmin::ArgminTree;
use loraserve::util::rng::Pcg32;

/// Bitwise reference for the router's argmin: the linear scan the
/// pre-index Toppings router ran per arrival (strict `<`, so ties go
/// to the lowest server id).
fn scan_argmin(loads: &[f64]) -> usize {
    let mut best = 0usize;
    for (s, &l) in loads.iter().enumerate().skip(1) {
        if l < loads[best] {
            best = s;
        }
    }
    best
}

#[test]
fn argmin_tree_matches_linear_scan_under_random_updates() {
    for n in [1usize, 2, 3, 5, 8, 64, 65, 100, 512, 1000] {
        let mut rng = Pcg32::new(7 + n as u64);
        let mut tree = ArgminTree::new(n);
        let mut loads = vec![f64::INFINITY; n];
        for step in 0..2000 {
            let s = rng.below(n as u64) as usize;
            // small discrete values force frequent exact ties, plus
            // INF masking and fractional loads
            let load = match step % 4 {
                0 => f64::INFINITY,
                1 => (rng.below(4) as f64) * 1.5,
                2 => rng.f64() * 10.0,
                _ => rng.below(3) as f64,
            };
            loads[s] = load;
            tree.update(s, load);
            assert_eq!(
                tree.argmin(),
                scan_argmin(&loads),
                "n={n} step={step}: argmin diverged from scan"
            );
        }
    }
}

#[test]
fn argmin_tree_ties_pick_lowest_index_like_the_scan() {
    let mut tree = ArgminTree::new(6);
    let loads = [3.0, 1.0, 1.0, 5.0, 1.0, 2.0];
    for (s, &l) in loads.iter().enumerate() {
        tree.update(s, l);
    }
    assert_eq!(tree.argmin(), 1);
    assert_eq!(scan_argmin(&loads), 1);
    // raising the winner hands the tie to the next-lowest index
    tree.update(1, 4.0);
    assert_eq!(tree.argmin(), 2);
}

fn trace_of(rps: f64, seed: u64) -> Trace {
    azure::generate(&AzureConfig {
        rps,
        duration: 120.0,
        seed,
        lengths: LengthModel::fixed(256, 16),
        ..Default::default()
    })
}

/// Same seed ⇒ byte-identical digest, sequential vs sharded. The
/// sharded run exercises the parallel-flush bookkeeping (rebuilt
/// backlog/argmin, touched-lane dirty marks); the sequential run
/// exercises the index-directed inline flush.
fn assert_digest_parity(trace: &Trace, base: &SimConfig, label: &str) {
    let mut seq = sim::run(trace, &base.clone().with_shards(1));
    let want = seq.to_json_string();
    assert!(seq.events > 0, "{label}: no events counted");
    for shards in [8usize] {
        let mut rep =
            sim::run(trace, &base.clone().with_shards(shards));
        assert_eq!(
            want,
            rep.to_json_string(),
            "{label}: digest diverged at shards={shards}"
        );
    }
}

#[test]
fn all_systems_digest_parity_with_indexed_coordinator() {
    let trace = trace_of(12.0, 11);
    for system in SystemKind::all() {
        let cluster = ClusterConfig {
            n_servers: 6,
            rebalance_period: 20.0,
            ..Default::default()
        };
        let cfg = SimConfig::new(cluster, system);
        assert_digest_parity(&trace, &cfg, system.label());
    }
}

#[test]
fn triggered_remote_attach_digest_parity() {
    // drift workload through the reactive path: trigger checks read
    // the delta-maintained utilization cache and the ring-buffer
    // projections every check period
    let trace = azure::generate(&AzureConfig {
        popularity: RankPopularity::ShiftingSkew,
        rps: 14.0,
        duration: 180.0,
        seed: 12,
        ..Default::default()
    });
    for mode in [RebalanceMode::Triggered, RebalanceMode::Hybrid] {
        let mut cluster = ClusterConfig {
            n_servers: 5,
            rebalance_period: 20.0,
            ..Default::default()
        };
        cluster.rebalance.mode = mode;
        cluster.rebalance.remote_attach = true;
        let cfg = SimConfig::new(cluster, SystemKind::LoraServe);
        assert_digest_parity(
            &trace,
            &cfg,
            &format!("reactive/{}", mode.label()),
        );
    }
}

#[test]
fn wide_fleet_toppings_digest_parity() {
    // a wider least-loaded fleet: every arrival is an epoch barrier
    // routed through the argmin tree, with most lanes idle — the
    // index-directed flush must still visit exactly the due lanes
    let trace = trace_of(30.0, 13);
    let cluster = ClusterConfig {
        n_servers: 32,
        ..Default::default()
    };
    let cfg = SimConfig::new(cluster, SystemKind::Toppings);
    assert_digest_parity(&trace, &cfg, "toppings-wide");
}
