//! Elastic-capacity invariants, end to end: pool coverage under
//! server removal (last-copy adapters are never dropped), routing
//! weight proportionality, autoscaler grow/shrink behavior for every
//! system, and the capacity-planner side of the paper's
//! fewer-GPUs-under-SLO claim.

use loraserve::autoscale::{plan_min_fleet, SloMetric, SloSpec};
use loraserve::config::{
    AutoscaleConfig, ClusterConfig, GpuSpec, ModelSpec,
};
use loraserve::coordinator::RoutingTable;
use loraserve::placement::Assignment;
use loraserve::pool::AdapterPool;
use loraserve::sim::{self, SimConfig, SystemKind};
use loraserve::trace::azure::{self, AzureConfig};
use loraserve::trace::production::{self, ProductionConfig};
use loraserve::trace::{LengthModel, Trace};
use loraserve::util::rng::Pcg32;
use loraserve::workload::{AdapterId, AdapterSet, ServerId};

// ---------------------------------------------------------------- pool

/// Shrink a fleet one server at a time down to a single survivor,
/// running the drain protocol's pool half (re-assign → migrate last
/// copies → GC). Coverage must hold after every single operation.
#[test]
fn pool_shrink_never_drops_last_copy() {
    let adapters = AdapterSet::uniform_per_rank(
        20,
        &[8, 16, 32, 64, 128],
        &ModelSpec::LLAMA_7B,
    );
    let gpu = GpuSpec::A100_40G;
    let mut rng = Pcg32::new(11);
    let n = 6usize;
    let initial: Vec<Vec<ServerId>> = (0..20)
        .map(|_| vec![rng.below(n as u64) as usize])
        .collect();
    let mut pool = AdapterPool::new(n, &initial);
    let mut live: Vec<ServerId> = (0..n).collect();
    while live.len() > 1 {
        let victim = live.remove(rng.below(live.len() as u64) as usize);
        // re-place everything onto the survivors (round-robin)
        let asg: Vec<Vec<ServerId>> = (0..20usize)
            .map(|a| vec![live[a % live.len()]])
            .collect();
        pool.apply_assignment(&asg);
        pool.check_coverage(20).unwrap();
        // RDMA-migrate the victim's last copies to their new homes
        for a in pool.evacuations(victim) {
            let tgt = asg[a as usize][0];
            let dt = pool
                .start_fetch(tgt, a, &adapters, &gpu)
                .expect("last copy must be fetchable");
            assert!(dt > 0.0);
            pool.check_coverage(20).unwrap();
            pool.finish_fetch(tgt, a);
            pool.check_coverage(20).unwrap();
        }
        // drained: the victim holds nothing and nothing was lost
        assert_eq!(
            pool.resident_count(victim),
            0,
            "server {victim} still holds copies after drain"
        );
        assert!(pool.evacuations(victim).is_empty());
        pool.check_coverage(20).unwrap();
    }
}

// -------------------------------------------------------------- router

/// `RoutingTable::route` must deliver traffic proportionally to φ for
/// every entry of a randomized table (the routing half of Fig 11).
#[test]
fn routing_table_weight_proportional() {
    for seed in 0..4u64 {
        let mut rng = Pcg32::new(100 + seed);
        let n_adapters = 20usize;
        let n_servers = 8usize;
        let mut asg = Assignment::new(n_adapters);
        for a in 0..n_adapters as AdapterId {
            let replicas = 1 + rng.below(3) as usize;
            let mut servers: Vec<usize> = (0..n_servers).collect();
            rng.shuffle(&mut servers);
            for &s in servers.iter().take(replicas) {
                asg.add(a, s, rng.range_f64(0.1, 1.0));
            }
        }
        asg.normalize();
        asg.validate(n_servers).unwrap();
        let table = RoutingTable::from_assignment(&asg);
        let trials = 30_000u64;
        let mut counts = vec![vec![0u64; n_servers]; n_adapters];
        for _ in 0..trials {
            for (a, row) in counts.iter_mut().enumerate() {
                row[table.route(a as AdapterId, &mut rng)] += 1;
            }
        }
        for (a, row) in counts.iter().enumerate() {
            let entry = table.entry(a as AdapterId);
            for &(s, phi) in entry {
                let f = row[s] as f64 / trials as f64;
                assert!(
                    (f - phi).abs() < 0.02,
                    "seed={seed} adapter={a} server={s} phi={phi} f={f}"
                );
            }
            // traffic only ever lands on listed servers
            let listed: u64 =
                entry.iter().map(|&(s, _)| row[s]).sum();
            assert_eq!(listed, trials, "adapter {a} leaked traffic");
        }
    }
}

// ----------------------------------------------------- elastic scaling

fn fixed_trace(rps: f64, seed: u64, duration: f64) -> Trace {
    azure::generate(&AzureConfig {
        rps,
        duration,
        seed,
        lengths: LengthModel::fixed(512, 16),
        ..Default::default()
    })
}

#[test]
fn autoscaler_grows_under_burst() {
    let trace = fixed_trace(30.0, 7, 180.0);
    let cluster = ClusterConfig {
        n_servers: 1,
        rebalance_period: 20.0,
        ..Default::default()
    };
    let acfg = AutoscaleConfig {
        min_servers: 1,
        max_servers: 6,
        decision_period: 10.0,
        cooldown: 20.0,
        provision_delay: 5.0,
        ..Default::default()
    };
    let rep = sim::run(
        &trace,
        &SimConfig::new(cluster, SystemKind::LoraServe)
            .with_autoscale(acfg),
    );
    assert_eq!(
        rep.completed + rep.timeouts,
        trace.requests.len() as u64,
        "requests lost across topology changes"
    );
    assert!(rep.fleet.scale_ups >= 1, "never scaled up under 30 rps");
    assert!(rep.fleet.peak_servers() > 1);
    assert!(rep.fleet.peak_servers() <= 6);
    // the timeline is a well-formed step function within bounds
    for w in rep.fleet.timeline.windows(2) {
        assert!(w[0].0 <= w[1].0);
    }
    for &(_, active) in &rep.fleet.timeline {
        assert!((1..=6).contains(&active));
    }
}

/// Scale-down exercises the drain-and-migrate protocol for every
/// system kind; the run's internal coverage debug-asserts plus request
/// conservation prove no adapter and no request is lost to a shrink.
#[test]
fn autoscaler_shrinks_when_idle_all_systems() {
    for system in [
        SystemKind::LoraServe,
        SystemKind::SLoraRandom,
        SystemKind::Toppings,
    ] {
        let trace = fixed_trace(2.0, 9, 240.0);
        let cluster = ClusterConfig {
            n_servers: 6,
            rebalance_period: 20.0,
            ..Default::default()
        };
        let acfg = AutoscaleConfig {
            min_servers: 1,
            max_servers: 6,
            decision_period: 10.0,
            cooldown: 15.0,
            provision_delay: 5.0,
            ..Default::default()
        };
        let rep = sim::run(
            &trace,
            &SimConfig::new(cluster, system).with_autoscale(acfg),
        );
        assert_eq!(
            rep.completed + rep.timeouts,
            trace.requests.len() as u64,
            "{}: requests lost during drain",
            system.label()
        );
        assert!(
            rep.fleet.scale_downs >= 1,
            "{}: never shrank at 2 rps on 6 servers",
            system.label()
        );
        assert!(
            rep.fleet.min_servers() < 6,
            "{}: fleet never actually shrank",
            system.label()
        );
        let last = rep.fleet.timeline.last().unwrap().1;
        assert!(last >= 1, "{}: shrank below min", system.label());
        // elastic fleet must burn fewer GPU-seconds than the fixed one
        let fixed = 6.0 * 4.0 * rep.fleet.duration();
        assert!(
            rep.fleet.gpu_seconds < fixed,
            "{}: {} !< {fixed}",
            system.label(),
            rep.fleet.gpu_seconds
        );
    }
}

#[test]
fn elastic_run_is_deterministic() {
    let trace = fixed_trace(20.0, 5, 150.0);
    let cluster = ClusterConfig {
        n_servers: 2,
        rebalance_period: 20.0,
        ..Default::default()
    };
    let acfg = AutoscaleConfig {
        min_servers: 1,
        max_servers: 5,
        decision_period: 10.0,
        cooldown: 20.0,
        provision_delay: 5.0,
        ..Default::default()
    };
    let cfg = SimConfig::new(cluster, SystemKind::LoraServe)
        .with_autoscale(acfg);
    let mut a = sim::run(&trace, &cfg);
    let mut b = sim::run(&trace, &cfg);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.ttft_p95(), b.ttft_p95());
    assert_eq!(a.fleet.timeline, b.fleet.timeline);
    assert_eq!(a.fleet.scale_ups, b.fleet.scale_ups);
    assert_eq!(a.fleet.scale_downs, b.fleet.scale_downs);
}

// ---------------------------------------------------- capacity planner

/// The acceptance check behind the "fewer GPUs" claim: on the default
/// production-style trace, LORASERVE's minimum SLO-meeting fleet is no
/// larger than the best baseline's.
#[test]
fn planner_loraserve_needs_no_more_servers_than_baselines() {
    let trace = production::generate(&ProductionConfig {
        n_adapters: 60,
        n_requests: (16.0 * 240.0) as usize,
        duration: 240.0,
        seed: 0,
        ..Default::default()
    })
    .scale_to_rps(16.0);
    let base = ClusterConfig::default();
    let spec = SloSpec::ttft_p95(base.slo.ttft_p95);
    let ls = plan_min_fleet(&trace, &base, SystemKind::LoraServe, &spec, 8)
        .min_servers
        .expect("loraserve must fit within 8 servers");
    let best_baseline = [
        SystemKind::SLoraRandom,
        SystemKind::SLoraContiguous,
        SystemKind::Toppings,
    ]
    .into_iter()
    .filter_map(|s| {
        plan_min_fleet(&trace, &base, s, &spec, 8).min_servers
    })
    .min();
    if let Some(b) = best_baseline {
        assert!(ls <= b, "loraserve needs {ls} servers, baseline {b}");
    }
}

#[test]
fn planner_e2e_metric() {
    let trace = fixed_trace(6.0, 3, 120.0);
    let base = ClusterConfig::default();
    let spec = SloSpec {
        metric: SloMetric::E2e,
        percentile: 95.0,
        threshold: 60.0,
    };
    let plan =
        plan_min_fleet(&trace, &base, SystemKind::LoraServe, &spec, 6);
    let n = plan.min_servers.expect("generous e2e slo must be met");
    assert!((1..=6).contains(&n));
    assert!(plan.observed_at_min().unwrap() > 0.0);
}
