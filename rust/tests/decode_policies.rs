//! Decode-composition invariants: plan well-formedness (slot cap,
//! disjointness, class homogeneity), the class-sub-batch fairness
//! bound, completion conservation across decode policies, and the
//! acceptance check behind the decode half of the scheduler seam —
//! rank-partitioned decode shrinking the high-rank decode-step share
//! and the low-rank classes' P99 TBT on a skewed-rank workload.
//!
//! (Bit-exact parity of the unified decode path with the pre-refactor
//! engine is certified by `tests/sched_policies.rs`'s
//! `fifo_engine_parity_all_systems` plus the unit test
//! `unified_decode_step_matches_legacy_formula` in `sim::server`.)

use loraserve::config::{
    ClusterConfig, DecodePolicyKind, ModelSpec, ServerConfig,
};
use loraserve::costmodel::CostModel;
use loraserve::sim::server::{
    ActiveReq, BatchPolicy, ClassSubBatchDecode, Fifo,
    RankPartitionedDecode, SimReq,
};
use loraserve::sim::{self, SimConfig, SimReport, SystemKind};
use loraserve::trace::Trace;
use loraserve::util::rng::Pcg32;
use loraserve::workload::{AdapterSet, Request};
use std::collections::{BTreeMap, BTreeSet};

fn cm() -> CostModel {
    CostModel::new(ServerConfig::default())
}

fn random_active(rng: &mut Pcg32, n: usize) -> Vec<ActiveReq> {
    (0..n)
        .map(|i| {
            let rank = [8u32, 16, 32, 64, 128][rng.below(5) as usize];
            ActiveReq {
                sreq: SimReq {
                    req: Request {
                        id: i as u64,
                        adapter: (i % 25) as u32,
                        prompt_len: 64 + rng.below(400) as u32,
                        output_len: 32,
                        arrival: 0.0,
                    },
                    rank,
                    adapter_bytes: 1 << 20,
                    est: 0.1,
                    remote: false,
                    uid: 0,
                },
                produced: 1 + rng.below(8) as u32,
                first_token_at: 0.0,
                seq: i as u64,
            }
        })
        .collect()
}

fn rank_of(active: &[ActiveReq], seq: u64) -> u32 {
    active.iter().find(|a| a.seq == seq).unwrap().sreq.rank
}

/// Property: composed plans never exceed the slot budget, never
/// duplicate or invent members, and keep every group rank-homogeneous
/// and non-empty; rank-partitioned covers the whole active set, and
/// class-subbatch respects its group bound.
#[test]
fn decode_plans_are_well_formed() {
    let cm = cm();
    for seed in 0..8u64 {
        let mut rng = Pcg32::new(300 + seed);
        for n in [0usize, 1, 2, 5, 12, 24] {
            let active = random_active(&mut rng, n);
            let slots = 24usize;
            let classes: BTreeSet<u32> =
                active.iter().map(|a| a.sreq.rank).collect();
            let mut partitioned =
                RankPartitionedDecode::new(Box::new(Fifo));
            let plan =
                partitioned.compose_decode(&active, slots, &cm, None);
            assert_eq!(
                plan.total_members(),
                n,
                "partitioned decodes everyone each round"
            );
            assert_eq!(plan.groups.len(), classes.len());
            let mut seen: BTreeSet<u64> = BTreeSet::new();
            for g in &plan.groups {
                assert!(!g.seqs.is_empty(), "empty group");
                let rank = rank_of(&active, g.seqs[0]);
                for &sq in &g.seqs {
                    assert!(seen.insert(sq), "seq {sq} in two groups");
                    assert_eq!(
                        rank_of(&active, sq),
                        rank,
                        "mixed-rank group"
                    );
                }
            }
            for k in [1usize, 2, 3] {
                let mut sub = ClassSubBatchDecode::new(
                    Box::new(Fifo),
                    k,
                );
                let plan =
                    sub.compose_decode(&active, slots, &cm, None);
                assert!(plan.groups.len() <= k.min(classes.len().max(1)));
                assert!(plan.total_members() <= slots);
                let mut seen: BTreeSet<u64> = BTreeSet::new();
                for g in &plan.groups {
                    assert!(!g.seqs.is_empty());
                    let rank = rank_of(&active, g.seqs[0]);
                    for &sq in &g.seqs {
                        assert!(seen.insert(sq));
                        assert_eq!(rank_of(&active, sq), rank);
                    }
                }
                if !active.is_empty() {
                    assert!(
                        !plan.groups.is_empty(),
                        "non-empty active must decode something"
                    );
                }
            }
        }
    }
}

/// Property: the class-sub-batch rotor never skips a non-empty class
/// for more than ⌈C/k⌉ − 1 consecutive rounds (the fairness bound),
/// for a stable co-resident class set.
#[test]
fn class_subbatch_fairness_bound() {
    let cm = cm();
    let mut rng = Pcg32::new(77);
    // 5 stable classes with randomized per-class populations
    let mut active = Vec::new();
    let mut seq = 0u64;
    for &rank in &[8u32, 16, 32, 64, 128] {
        for _ in 0..1 + rng.below(4) {
            let mut a = random_active(&mut rng, 1).pop().unwrap();
            a.sreq.rank = rank;
            a.seq = seq;
            seq += 1;
            active.push(a);
        }
    }
    let n_classes = 5usize;
    for k in [1usize, 2, 3] {
        let bound = n_classes.div_ceil(k); // served ≥ once per `bound`
        let mut pol = ClassSubBatchDecode::new(Box::new(Fifo), k);
        let mut waited: BTreeMap<u32, usize> = BTreeMap::new();
        for round in 0..30 {
            let plan = pol.compose_decode(&active, 24, &cm, None);
            let served: BTreeSet<u32> = plan
                .groups
                .iter()
                .map(|g| rank_of(&active, g.seqs[0]))
                .collect();
            for &rank in &[8u32, 16, 32, 64, 128] {
                if served.contains(&rank) {
                    waited.insert(rank, 0);
                } else {
                    let w = waited.entry(rank).or_insert(0);
                    *w += 1;
                    assert!(
                        *w < bound,
                        "k={k} round={round}: class {rank} skipped \
                         {w} consecutive rounds (bound {bound})"
                    );
                }
            }
        }
    }
}

/// The skewed-rank acceptance workload: two classes, ~85% rank-8
/// traffic with a rank-128 minority always co-resident, long outputs
/// so the decode tail dominates. Deterministic per seed.
fn two_class_trace(rps: f64, duration: f64, seed: u64) -> Trace {
    let adapters = AdapterSet::uniform_per_rank(
        10,
        &[8, 128],
        &ModelSpec::LLAMA_7B,
    );
    let lo_ids: Vec<u32> = adapters
        .iter()
        .filter(|a| a.rank == 8)
        .map(|a| a.id)
        .collect();
    let hi_ids: Vec<u32> = adapters
        .iter()
        .filter(|a| a.rank == 128)
        .map(|a| a.id)
        .collect();
    let mut rng = Pcg32::new(seed);
    let n = (rps * duration) as usize;
    let requests: Vec<Request> = (0..n)
        .map(|i| {
            let pool = if rng.f64() < 0.85 { &lo_ids } else { &hi_ids };
            Request {
                id: i as u64,
                adapter: pool[rng.below(pool.len() as u64) as usize],
                prompt_len: 256,
                output_len: 64,
                arrival: duration * i as f64 / n as f64,
            }
        })
        .collect();
    Trace::new("two-class-skew", adapters, requests)
}

fn run_decode(
    trace: &Trace,
    decode: DecodePolicyKind,
) -> SimReport {
    let cluster = ClusterConfig {
        n_servers: 1,
        rebalance_period: 30.0,
        ..Default::default()
    };
    sim::run(
        trace,
        &SimConfig::new(cluster, SystemKind::SLoraRandom)
            .with_params(|p| p.decode(decode)),
    )
}

/// The acceptance check behind the decode seam: on the skewed-rank
/// decode-heavy workload, rank-partitioned (and class-sub-batch)
/// decode shrinks the cluster-wide high-rank decode-step share, wipes
/// out decode-side pad-rank waste, and lowers the rank-8 class's P99
/// TBT relative to unified max-rank decode — without losing a single
/// request.
#[test]
fn rank_aware_decode_beats_unified_on_skewed_ranks() {
    let trace = two_class_trace(6.0, 300.0, 5);
    let mut unified = run_decode(&trace, DecodePolicyKind::Unified);
    let mut partitioned =
        run_decode(&trace, DecodePolicyKind::RankPartitioned);
    let subbatch = run_decode(
        &trace,
        DecodePolicyKind::ClassSubBatch { max_groups: 2 },
    );
    for (rep, label) in [
        (&unified, "unified"),
        (&partitioned, "rank-partitioned"),
        (&subbatch, "class-subbatch"),
    ] {
        assert_eq!(
            rep.completed + rep.timeouts,
            trace.requests.len() as u64,
            "{label}: requests lost"
        );
        assert!(rep.decode_steps > 0, "{label}: no decode steps");
    }
    // structural: unified mixes ranks in decode and burns pad work;
    // the rank-aware compositions never do
    assert!(unified.mixed_decode_steps > 0);
    assert!(unified.decode_pad_rank > 0);
    assert_eq!(partitioned.mixed_decode_steps, 0);
    assert_eq!(partitioned.decode_pad_rank, 0);
    assert_eq!(subbatch.mixed_decode_steps, 0);
    assert_eq!(subbatch.decode_pad_rank, 0);
    // behavioral: the share of decode steps billed at a high rank
    // collapses once the rank-128 minority stops dragging every step
    assert!(
        partitioned.highrank_decode_share()
            < unified.highrank_decode_share(),
        "partitioned {} !< unified {}",
        partitioned.highrank_decode_share(),
        unified.highrank_decode_share()
    );
    assert!(
        subbatch.highrank_decode_share()
            < unified.highrank_decode_share(),
        "subbatch {} !< unified {}",
        subbatch.highrank_decode_share(),
        unified.highrank_decode_share()
    );
    // and the low-rank class's decode tail gets faster
    let lo_unified = unified.tbt_p99_class(8);
    let lo_partitioned = partitioned.tbt_p99_class(8);
    assert!(
        lo_partitioned < lo_unified,
        "rank-8 p99 TBT: partitioned {lo_partitioned} !< unified \
         {lo_unified}"
    );
}

/// The `--decode-policy` knob threads end to end: the report labels
/// the policy it ran, and the unified default matches an explicit
/// unified run exactly.
#[test]
fn decode_knob_threads_through_config() {
    let trace = two_class_trace(3.0, 90.0, 9);
    let cluster = ClusterConfig {
        n_servers: 1,
        rebalance_period: 30.0,
        ..Default::default()
    };
    let default_run = sim::run(
        &trace,
        &SimConfig::new(cluster.clone(), SystemKind::SLoraRandom),
    );
    assert_eq!(default_run.decode_policy, "unified");
    let explicit = sim::run(
        &trace,
        &SimConfig::new(cluster.clone(), SystemKind::SLoraRandom)
            .with_params(|p| p.decode(DecodePolicyKind::Unified)),
    );
    assert_eq!(default_run.completed, explicit.completed);
    assert_eq!(
        default_run.makespan.to_bits(),
        explicit.makespan.to_bits()
    );
    // cluster-config seeding (the JSON/CLI path) reaches the servers
    let seeded = ClusterConfig {
        n_servers: 1,
        rebalance_period: 30.0,
        decode_policy: DecodePolicyKind::RankPartitioned,
        ..Default::default()
    };
    let rep = sim::run(
        &trace,
        &SimConfig::new(seeded, SystemKind::SLoraRandom),
    );
    assert_eq!(rep.decode_policy, "rank-partitioned");
    assert_eq!(rep.mixed_decode_steps, 0);
}
