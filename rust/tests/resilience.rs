//! Failure-injection acceptance: crash + recovery epochs keep the
//! sharded engine byte-identical to sequential, the crashed-server
//! request ledger conserves (requeue and fail modes), last-copy host
//! re-fetches are charged to `fetch_stall` in the attribution, and
//! the rebalance modes keep their resilience ordering through the
//! crash window.

use loraserve::config::{ClusterConfig, RebalanceMode};
use loraserve::figures::resilience::{
    p99_degradation, resilience_scenario, resilience_trace,
};
use loraserve::obs::ObsConfig;
use loraserve::sim::scenario::{
    FailureConfig, RegionConfig, ScenarioConfig,
};
use loraserve::sim::{self, run_observed, SimConfig, SystemKind};
use loraserve::trace::scenario::{generate, ScenarioTraceConfig};
use loraserve::trace::Trace;

fn cluster(n: usize) -> ClusterConfig {
    ClusterConfig {
        n_servers: n,
        rebalance_period: 30.0,
        ..Default::default()
    }
}

/// A crash process dense enough that a short trace reliably sees at
/// least one crash + recovery.
fn crash_scenario(requeue: bool) -> ScenarioConfig {
    ScenarioConfig {
        failures: FailureConfig {
            enabled: true,
            mtbf: 25.0,
            mttr: 30.0,
            start: 20.0,
            max_crashes: 2,
            requeue,
        },
        regions: RegionConfig::default(),
    }
}

/// Churn trace hot enough that the victim has a deep queue at crash
/// time, so the requeue/fail ledgers are exercised non-trivially.
fn hot_trace(seed: u64) -> Trace {
    generate(&ScenarioTraceConfig {
        n_adapters: 24,
        rps: 40.0,
        duration: 120.0,
        seed,
        ..Default::default()
    })
}

/// Crash and recovery are coordinator-epoch events: the same seed must
/// produce a byte-identical report digest at shards 1, 2, and 8 with
/// the failure process live — for the table-routed distributed-pool
/// system and the least-loaded replicated one alike.
#[test]
fn crash_epochs_shard_invariant() {
    let trace = resilience_trace(150.0, 7);
    for system in [SystemKind::LoraServe, SystemKind::Toppings] {
        let cfg = SimConfig::new(cluster(4), system)
            .with_params(|p| p.scenario(crash_scenario(true)));
        let mut seq = sim::run(&trace, &cfg.clone().with_shards(1));
        assert!(
            seq.crashes > 0,
            "{}: failure process never fired",
            system.label()
        );
        assert!(seq.recoveries > 0, "{}: no recovery", system.label());
        let want = seq.to_json_string();
        for shards in [2usize, 8] {
            let mut rep =
                sim::run(&trace, &cfg.clone().with_shards(shards));
            assert_eq!(
                want,
                rep.to_json_string(),
                "{}: digest diverged at shards={shards}",
                system.label()
            );
        }
    }
}

/// The crashed-server request ledger. Requeue mode: every request the
/// crash recovered finishes (or times out) somewhere else, so the
/// usual conservation law holds unchanged. Fail mode: the recovered
/// requests are failed outright and the ledger balances only with the
/// `crash_failed` column added.
#[test]
fn crashed_server_request_conservation() {
    let trace = hot_trace(11);
    let run = |requeue: bool| {
        sim::run(
            &trace,
            &SimConfig::new(cluster(3), SystemKind::LoraServe)
                .with_params(|p| p.scenario(crash_scenario(requeue))),
        )
    };
    let rq = run(true);
    assert!(rq.crashes >= 1, "no crash fired");
    assert!(rq.crash_requeued > 0, "victim was idle at crash time");
    assert_eq!(rq.crash_failed, 0);
    assert_eq!(
        rq.completed + rq.timeouts,
        trace.requests.len() as u64,
        "requeue mode lost requests"
    );
    let fl = run(false);
    assert!(fl.crashes >= 1, "no crash fired");
    assert!(fl.crash_failed > 0, "victim was idle at crash time");
    assert_eq!(fl.crash_requeued, 0);
    assert_eq!(
        fl.completed + fl.timeouts + fl.crash_failed,
        trace.requests.len() as u64,
        "fail mode ledger does not balance"
    );
}

/// A crash that takes an adapter's last copy re-fetches it from host
/// memory (`host_fetches`), and the requests that requeue onto the
/// still-fetching target are charged the wait as `fetch_stall` in the
/// SLO attribution.
#[test]
fn last_copy_refetch_charges_fetch_stall() {
    let trace = hot_trace(13);
    let (mut rep, _) = run_observed(
        &trace,
        &SimConfig::new(cluster(3), SystemKind::LoraServe)
            .with_params(|p| p.scenario(crash_scenario(true)))
            .with_obs(ObsConfig {
                attrib: true,
                ..Default::default()
            }),
    );
    assert!(rep.crashes >= 1, "no crash fired");
    assert!(
        rep.host_fetches > 0,
        "no last copy was lost — the crash path never paged from host"
    );
    let a = rep.attribution.expect("summary attached to the report");
    assert!(a.all.n > 0);
    assert!(
        a.all.fetch_stall > 0.0,
        "host re-fetch waits never charged to fetch_stall"
    );
    assert!(a.all.recon < 1e-6, "recon={}", a.all.recon);
    // the digest carries the crash bookkeeping
    let digest = rep.to_json_string();
    for key in ["\"crashes\"", "\"recoveries\"", "\"host_fetches\""] {
        assert!(digest.contains(key), "digest missing {key}");
    }
}

/// The resilience ordering the figure reports: through an identical
/// crash window on the identical churn trace, triggered+remote-attach
/// rebalancing must not degrade p99 TTFT more than the open-loop
/// periodic timer (small additive tolerance for sampling noise — the
/// full-size figure shows the strict gap).
#[test]
fn triggered_remote_attach_no_worse_than_periodic_through_crash() {
    let trace = resilience_trace(300.0, 5);
    // period longer than the trace: the periodic arm cannot react to
    // the crash at all
    let cl = ClusterConfig {
        n_servers: 4,
        rebalance_period: 600.0,
        ..Default::default()
    };
    let mut sc = resilience_scenario();
    sc.failures.start = 50.0;
    sc.failures.mtbf = 30.0;
    sc.failures.mttr = 120.0;
    sc.failures.max_crashes = 1;
    let warmup = sc.failures.start;
    let deg_per = p99_degradation(
        &trace,
        &cl,
        RebalanceMode::Periodic,
        false,
        sc,
        warmup,
    );
    let deg_tri = p99_degradation(
        &trace,
        &cl,
        RebalanceMode::Triggered,
        true,
        sc,
        warmup,
    );
    assert!(
        deg_per.is_finite() && deg_tri.is_finite(),
        "degradations must be measurable: per={deg_per} tri={deg_tri}"
    );
    assert!(
        deg_tri <= deg_per + 0.050,
        "triggered+remote p99 degradation {deg_tri:.4}s exceeds \
         periodic {deg_per:.4}s"
    );
}
