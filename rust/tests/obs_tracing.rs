//! Observability acceptance criteria (the flight-recorder PR):
//!
//! * every obs knob off — and trace/metrics on — leaves the report
//!   digest **bit-identical** to an unobserved run (the subsystem is
//!   zero-cost when it only watches);
//! * two same-seed observed runs export **byte-identical** trace and
//!   metrics files (the CI determinism gate `cmp`s them);
//! * the per-request latency decomposition reconciles with the
//!   measured TTFT/E2E to 1e-6 s on a mixed trace that exercises
//!   decode preemption *and* remote-attach serving;
//! * the flight-recorder ring keeps exactly the last N events;
//! * emitted traces pass the span-nesting / async-balance checker the
//!   `trace-check` CLI subcommand runs in CI;
//! * the queue-pressure trigger signal and remote-attach promotion
//!   satellites do what their knobs say (and stay inert by default).

use loraserve::config::{
    ClusterConfig, DecodePolicyKind, RebalanceConfig, RebalanceMode,
    SloFeedbackConfig,
};
use loraserve::figures::drift::{drift_rebalance, drift_trace};
use loraserve::obs::{check_spans_nest, ObsConfig};
use loraserve::sim::{self, run_observed, SimConfig, SystemKind};
use loraserve::trace::Trace;
use loraserve::util::json::{parse, Json};

fn drift_cluster(rebalance: RebalanceConfig) -> ClusterConfig {
    let mut c = ClusterConfig {
        n_servers: 4,
        rebalance_period: 60.0,
        ..Default::default()
    };
    c.rebalance = rebalance;
    c
}

fn mixed_trace() -> Trace {
    drift_trace(20, 8.0, 300.0, 5)
}

/// Count non-metadata events in an exported Chrome trace.
fn event_count(trace_json: &str) -> usize {
    let v = parse(trace_json).unwrap();
    v.get("traceEvents")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) != Some("M"))
        .count()
}

/// Tracing + metrics observe the run without perturbing it: the
/// report digest is byte-for-byte the digest of an unobserved run.
#[test]
fn tracing_and_metrics_leave_digest_bit_identical() {
    let trace = mixed_trace();
    let rb = drift_rebalance(RebalanceMode::Triggered, true);
    let mut base = sim::run(
        &trace,
        &SimConfig::new(drift_cluster(rb), SystemKind::LoraServe),
    );
    let (mut watched, out) = run_observed(
        &trace,
        &SimConfig::new(drift_cluster(rb), SystemKind::LoraServe)
            .with_obs(ObsConfig {
                trace: true,
                metrics: true,
                ..Default::default()
            }),
    );
    assert_eq!(
        base.to_json_string(),
        watched.to_json_string(),
        "observing a run must not change its digest"
    );
    assert!(out.trace_json.is_some());
    assert!(out.metrics_text.is_some());
    // the digest carries the new counters even when nothing fired
    assert!(base.to_json_string().contains("\"promotions\":"));
}

/// Same seed, same config ⇒ byte-identical trace and metrics exports
/// (what the CI determinism gate compares across two fresh runs).
#[test]
fn same_seed_exports_are_byte_identical() {
    let trace = mixed_trace();
    let run = || {
        let rb = drift_rebalance(RebalanceMode::Triggered, true);
        run_observed(
            &trace,
            &SimConfig::new(drift_cluster(rb), SystemKind::LoraServe)
                .with_obs(ObsConfig {
                    trace: true,
                    metrics: true,
                    attrib: true,
                    ..Default::default()
                }),
        )
        .1
    };
    let (a, b) = (run(), run());
    assert_eq!(a.trace_json, b.trace_json);
    assert_eq!(a.metrics_text, b.metrics_text);
    assert!(event_count(a.trace_json.as_deref().unwrap()) > 1000);
    // Prometheus text carries the end-of-run counter sync
    let prom = a.metrics_text.unwrap();
    assert!(prom.contains("sim_completed_total"));
    assert!(prom.contains("# TYPE"));
}

/// Emitted traces pass the same structural checker the CI smoke runs
/// via `loraserve trace-check`: X-spans nest per track, every async
/// end has a begin.
#[test]
fn real_trace_passes_span_nesting_checker() {
    let trace = mixed_trace();
    let rb = drift_rebalance(RebalanceMode::Triggered, true);
    let (_, out) = run_observed(
        &trace,
        &SimConfig::new(drift_cluster(rb), SystemKind::LoraServe)
            .with_obs(ObsConfig {
                trace: true,
                ..Default::default()
            }),
    );
    let text = out.trace_json.unwrap();
    check_spans_nest(&text).unwrap();
    // the request lifecycle and control plane both made it in
    for needle in ["\"req\"", "prefill", "decode", "trigger_check"] {
        assert!(text.contains(needle), "trace missing {needle}");
    }
}

/// `--trace-last N` runs the sink as a flight recorder: exactly the
/// last N events survive, and the export reports how many fell off.
#[test]
fn flight_recorder_ring_keeps_exactly_last_n() {
    let trace = mixed_trace();
    let observe = |last: Option<usize>| {
        let rb = drift_rebalance(RebalanceMode::Triggered, true);
        run_observed(
            &trace,
            &SimConfig::new(drift_cluster(rb), SystemKind::LoraServe)
                .with_obs(ObsConfig {
                    trace: true,
                    trace_last: last,
                    ..Default::default()
                }),
        )
        .1
        .trace_json
        .unwrap()
    };
    let full = observe(None);
    let ring = observe(Some(64));
    let total = event_count(&full);
    assert!(total > 64, "run too small to exercise the ring: {total}");
    assert_eq!(event_count(&ring), 64);
    let dropped = parse(&ring)
        .unwrap()
        .get("droppedEvents")
        .and_then(Json::as_f64)
        .unwrap() as usize;
    assert_eq!(dropped, total - 64);
    // the ring's last event is the full trace's last event
    let last_of = |text: &str| {
        let v = parse(text).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        let e = evs.last().unwrap();
        (
            e.get("name").and_then(Json::as_str).unwrap().to_string(),
            e.get("ts").and_then(Json::as_f64).unwrap(),
        )
    };
    assert_eq!(last_of(&full), last_of(&ring));
}

/// The exact-decomposition contract on a trace that exercises every
/// component: queueing, fetch stalls, rank-partitioned decode with
/// SLO-feedback preemption, and remote-attach serving. Every
/// completed request's summed components must reconcile with its
/// measured TTFT and E2E latency to 1e-6 s.
#[test]
fn attribution_reconciles_on_mixed_preempt_remote_trace() {
    let trace = mixed_trace();
    let rb = drift_rebalance(RebalanceMode::Triggered, true);
    let (mut rep, out) = run_observed(
        &trace,
        &SimConfig::new(drift_cluster(rb), SystemKind::LoraServe)
            .with_params(|p| {
                p.decode(DecodePolicyKind::RankPartitioned)
                    .slo(SloFeedbackConfig {
                        enabled: true,
                        ttft_target: 0.08,
                        tbt_target: 0.05,
                        preempt_decode: true,
                        pressure_theta: 0.5,
                    })
            })
            .with_obs(ObsConfig {
                attrib: true,
                ..Default::default()
            }),
    );
    // the run really is mixed: both hard-to-attribute paths fired
    assert!(rep.decode_preemptions > 0, "no decode preemption");
    assert!(rep.remote_served > 0, "no remote-attach serving");

    let recs = out.attrib.expect("attrib enabled");
    let mut checked = 0u64;
    let (mut saw_preempt, mut saw_remote) = (false, false);
    for r in recs.iter().filter(|r| r.used && r.done) {
        assert!(
            (r.ttft_sum() - r.ttft).abs() < 1e-6,
            "ttft decomposition off by {} at arrival {}",
            (r.ttft_sum() - r.ttft).abs(),
            r.arrival
        );
        assert!(
            (r.e2e_sum() - r.e2e).abs() < 1e-6,
            "e2e decomposition off by {} at arrival {}",
            (r.e2e_sum() - r.e2e).abs(),
            r.arrival
        );
        saw_preempt |= r.preempt_delay > 0.0;
        saw_remote |= r.prefill_remote + r.decode_remote > 0.0;
        checked += 1;
    }
    assert!(checked > 100, "only {checked} completions checked");
    assert!(saw_preempt, "no request charged preempt_delay");
    assert!(saw_remote, "no request charged a remote-attach penalty");

    // the aggregated summary reports the same reconciliation bound
    // and lands in the digest
    let a = rep.attribution.expect("summary attached to the report");
    assert!(a.all.recon < 1e-6, "recon={}", a.all.recon);
    assert!(a.tail.recon < 1e-6, "recon={}", a.tail.recon);
    // measured (post-warmup) completions are a subset of done records
    assert!(a.all.n > 0 && a.all.n <= checked);
    assert!(rep.to_json_string().contains("\"attribution\""));
}

/// Satellite: the opt-in queue-pressure OR-term. With the imbalance
/// threshold parked out of reach, the trigger can only fire through
/// queue depth / fetch-stall pressure — off by default, live when
/// `queue_signal` is set.
#[test]
fn queue_pressure_signal_fires_trigger_only_when_enabled() {
    let trace = mixed_trace();
    let run = |queue_signal: bool| {
        let mut rb = drift_rebalance(RebalanceMode::Triggered, false);
        rb.imbalance_threshold = 1e9; // imbalance alone can never fire
        rb.queue_signal = queue_signal;
        rb.queue_depth_hot = 0.25; // any sustained backlog counts
        rb.stall_hot = 1e9; // isolate the depth term
        sim::run(
            &trace,
            &SimConfig::new(drift_cluster(rb), SystemKind::LoraServe),
        )
    };
    let quiet = run(false);
    assert_eq!(
        quiet.triggered_rebalances, 0,
        "default-off signal must leave the trigger silent"
    );
    let pressed = run(true);
    assert!(pressed.trigger_checks > 0);
    assert!(
        pressed.triggered_rebalances > 0,
        "queue pressure never fired the trigger"
    );
}

/// Satellite: remote-attach promotion. With `promote_hot = 1` every
/// remotely-served adapter earns a materialized copy at the next
/// trigger check; with the default 0 nothing is ever promoted.
#[test]
fn remote_hotness_promotes_adapters_to_local_copies() {
    let trace = mixed_trace();
    let run = |promote_hot: u64| {
        let mut rb = drift_rebalance(RebalanceMode::Triggered, true);
        rb.promote_hot = promote_hot;
        sim::run(
            &trace,
            &SimConfig::new(drift_cluster(rb), SystemKind::LoraServe),
        )
    };
    let off = run(0);
    assert!(off.remote_served > 0, "no remote serving to promote");
    assert_eq!(off.promotions, 0, "promotion must be off by default");
    let on = run(1);
    assert!(
        on.promotions > 0,
        "hot remote adapters were never materialized"
    );
}
