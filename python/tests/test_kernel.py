"""L1 correctness: Pallas multi-adapter kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/ranks/adapter counts; fixed parametrized cases
pin the edge cases (single block, single adapter, rank == r_max, rank 1).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sgmv

jax.config.update("jax_platform_name", "cpu")


def make_bank(key, d, r_max, ranks):
    """Random adapter bank with given true ranks."""
    adapters = []
    for i, r in enumerate(ranks):
        ka, kb = jax.random.split(jax.random.fold_in(key, i))
        a = jax.random.normal(ka, (d, r)) * 0.3
        b = jax.random.normal(kb, (r, d)) * 0.3
        adapters.append((a, b, float(2 * r)))
    return sgmv.stack_adapters(adapters, d, r_max)


def run_pair(key, d, r_max, ranks, bseg, bt):
    la, lb, sc, rk = make_bank(key, d, r_max, ranks)
    t = len(bseg) * bt
    x = jax.random.normal(jax.random.fold_in(key, 999), (t, d))
    bseg = jnp.array(bseg, jnp.int32)
    seg = sgmv.expand_block_seg(bseg, bt)
    want = ref.lora_delta_ref(x, seg, la, lb) * sc[seg][:, None]
    got_padded = sgmv.bgmv_padded(x, bseg, la, lb, sc, block_tokens=bt)
    got_masked = sgmv.sgmv_rank_aware(x, bseg, la, lb, sc, rk,
                                      block_tokens=bt)
    return np.asarray(want), np.asarray(got_padded), np.asarray(got_masked)


@pytest.mark.parametrize("d,r_max,ranks,bseg,bt", [
    (16, 4, [4], [0], 4),                      # single block, single adapter
    (32, 8, [8, 8], [0, 1, 0], 8),             # rank == r_max everywhere
    (32, 16, [1, 16], [1, 0, 1, 1], 4),        # rank 1 vs full
    (64, 32, [2, 4, 8, 16, 32], [4, 3, 2, 1, 0, 0], 8),  # all rank classes
    (8, 2, [2, 1], [0, 1], 1),                 # block_tokens = 1 (decode)
])
def test_kernels_match_ref_fixed(d, r_max, ranks, bseg, bt):
    key = jax.random.PRNGKey(hash((d, r_max, bt)) % 2**31)
    want, got_p, got_m = run_pair(key, d, r_max, ranks, bseg, bt)
    np.testing.assert_allclose(got_p, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_m, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from([8, 16, 32, 64]),
    r_max_log=st.integers(0, 5),
    n_adapters=st.integers(1, 6),
    n_blocks=st.integers(1, 6),
    bt=st.sampled_from([1, 2, 4, 8]),
)
def test_kernels_match_ref_hypothesis(seed, d, r_max_log, n_adapters,
                                      n_blocks, bt):
    r_max = 2 ** r_max_log
    key = jax.random.PRNGKey(seed)
    rank_key, seg_key = jax.random.split(key)
    # true ranks: random powers of two <= r_max
    ranks = [int(2 ** int(v)) for v in
             jax.random.randint(rank_key, (n_adapters,), 0, r_max_log + 1)]
    bseg = [int(v) for v in
            jax.random.randint(seg_key, (n_blocks,), 0, n_adapters)]
    want, got_p, got_m = run_pair(key, d, r_max, ranks, bseg, bt)
    np.testing.assert_allclose(got_p, want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got_m, want, rtol=2e-4, atol=2e-4)


def test_rank_mask_exact_under_garbage_padding():
    """Only the rank-aware kernel must survive garbage in the padding."""
    key = jax.random.PRNGKey(7)
    d, r_max = 32, 16
    la, lb, sc, rk = make_bank(key, d, r_max, [4, 16, 2])
    bseg = jnp.array([0, 2, 1], jnp.int32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (3 * 8, d))
    seg = sgmv.expand_block_seg(bseg, 8)
    want = ref.lora_delta_masked_ref(x, seg, la, lb, rk) * sc[seg][:, None]

    # poison the padded regions: A cols >= rank AND B rows >= rank (either
    # alone is annihilated by the other side's zero padding)
    pad_a = (jnp.arange(r_max)[None, None, :] >= rk[:, None, None]) * 13.0
    pad_b = (jnp.arange(r_max)[None, :, None] >= rk[:, None, None]) * 13.0
    la_bad = la + pad_a
    lb_bad = lb + pad_b
    got = sgmv.sgmv_rank_aware(x, bseg, la_bad, lb_bad, sc, rk,
                               block_tokens=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    # sanity: the padded kernel is NOT robust to this (it must differ)
    got_padded = sgmv.bgmv_padded(x, bseg, la_bad, lb_bad, sc,
                                  block_tokens=8)
    assert not np.allclose(np.asarray(got_padded), np.asarray(want),
                           rtol=1e-3, atol=1e-3)


def test_scaling_is_alpha_over_rank():
    key = jax.random.PRNGKey(3)
    d = 16
    la, lb, sc, rk = make_bank(key, d, 8, [8, 4])
    # stack_adapters stores alpha/r; bank alpha = 2r, so scaling == 2.
    np.testing.assert_allclose(np.asarray(sc), [2.0, 2.0])


def test_zero_adapter_gives_zero_delta():
    d, r_max = 16, 8
    la = jnp.zeros((2, d, r_max))
    lb = jnp.zeros((2, r_max, d))
    sc = jnp.ones((2,))
    x = jnp.ones((8, d))
    out = sgmv.bgmv_padded(x, jnp.array([0], jnp.int32), la, lb, sc,
                           block_tokens=8)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((8, d)))


def test_block_seg_expansion():
    bseg = jnp.array([3, 1, 4], jnp.int32)
    seg = sgmv.expand_block_seg(bseg, 2)
    np.testing.assert_array_equal(np.asarray(seg), [3, 3, 1, 1, 4, 4])


def test_bad_shapes_rejected():
    d, r_max = 16, 8
    la = jnp.zeros((1, d, r_max))
    lb = jnp.zeros((1, r_max, d))
    sc = jnp.ones((1,))
    x = jnp.ones((7, d))  # 7 not a multiple of block_tokens=8
    with pytest.raises(AssertionError):
        sgmv.bgmv_padded(x, jnp.array([0], jnp.int32), la, lb, sc,
                         block_tokens=8)
    with pytest.raises(AssertionError):
        sgmv.stack_adapters([(jnp.zeros((d, 16)), jnp.zeros((16, d)), 1.0)],
                            d, r_max)  # rank > r_max
