"""L2 correctness: prefill/decode consistency, LoRA plumbing, ABI shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import sgmv

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=64,
                    max_seq=24, r_max=8, block_tokens=8)


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, CFG)
    bank = []
    for i, r in enumerate([2, 8, 4]):
        ka, kb = jax.random.split(jax.random.fold_in(key, 100 + i))
        bank.append((jax.random.normal(ka, (CFG.d_model, r)) * 0.1,
                     jax.random.normal(kb, (r, CFG.d_model)) * 0.1,
                     float(r)))
    la, lb, sc, rk = sgmv.stack_adapters(bank, CFG.d_model, CFG.r_max)
    return params, la, lb, sc


def _prefill_one(params, la, lb, sc, prompt, adapter, lp=8):
    tokens = jnp.zeros((1, lp), jnp.int32).at[0, :len(prompt)].set(
        jnp.array(prompt, jnp.int32))
    bseg = jnp.full((lp // CFG.block_tokens,), adapter, jnp.int32)
    lens = jnp.array([len(prompt)], jnp.int32)
    return M.prefill(params, la, lb, sc, tokens, bseg, lens, CFG)


def test_prefill_shapes(setup):
    params, la, lb, sc = setup
    logits, kc, vc = _prefill_one(params, la, lb, sc, [1, 2, 3], 0)
    assert logits.shape == (1, CFG.vocab)
    assert kc.shape == (CFG.n_layers, 1, CFG.max_seq, CFG.n_heads,
                        CFG.head_dim)
    assert vc.shape == kc.shape
    assert np.all(np.isfinite(np.asarray(logits)))


def test_prefill_ignores_padding(setup):
    """Right-padding must not change the logits at the last real token."""
    params, la, lb, sc = setup
    prompt = [5, 9, 11]
    l1, _, _ = _prefill_one(params, la, lb, sc, prompt, 0, lp=8)
    tokens = jnp.zeros((1, 16), jnp.int32).at[0, :3].set(
        jnp.array(prompt, jnp.int32)).at[0, 3:].set(42)  # junk padding
    bseg = jnp.full((2,), 0, jnp.int32)
    l2, _, _ = M.prefill(params, la, lb, sc, tokens, bseg,
                         jnp.array([3], jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-5)


def test_adapter_changes_output(setup):
    """Different adapters on the same prompt give different logits."""
    params, la, lb, sc = setup
    l0, _, _ = _prefill_one(params, la, lb, sc, [1, 2, 3, 4], 0)
    l1, _, _ = _prefill_one(params, la, lb, sc, [1, 2, 3, 4], 1)
    assert not np.allclose(np.asarray(l0), np.asarray(l1), atol=1e-4)


def test_zero_adapter_equals_base_model(setup):
    """A zeroed adapter slot must reproduce the frozen base model."""
    params, la, lb, sc = setup
    la0, lb0 = jnp.zeros_like(la), jnp.zeros_like(lb)
    l0, _, _ = M.prefill(params, la0, lb0, sc,
                         jnp.array([[1, 2, 3, 4, 0, 0, 0, 0]], jnp.int32),
                         jnp.array([0], jnp.int32),
                         jnp.array([4], jnp.int32), CFG)
    # base model := adapter with zero delta, any slot
    l1, _, _ = M.prefill(params, la0, lb0, sc,
                         jnp.array([[1, 2, 3, 4, 0, 0, 0, 0]], jnp.int32),
                         jnp.array([2], jnp.int32),
                         jnp.array([4], jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-5)


def test_decode_matches_prefill_teacher_forced(setup):
    """Decoding token t with the cache must equal prefilling prompt+t.

    This is the KV-cache equivalence invariant: the functional cache path
    and the full-attention path are the same computation.
    """
    params, la, lb, sc = setup
    prompt = [3, 7, 1]
    nxt = 9
    # path A: prefill the 4-token prompt directly
    la_, _, _ = _prefill_one(params, la, lb, sc, prompt + [nxt], 1)
    # path B: prefill 3 tokens, then decode token `nxt` at pos 3
    _, kc, vc = _prefill_one(params, la, lb, sc, prompt, 1)
    lb_, kc, vc = M.decode(params, la, lb, sc, kc, vc,
                           jnp.array([nxt], jnp.int32),
                           jnp.array([1], jnp.int32),
                           jnp.array([3], jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(la_), np.asarray(lb_),
                               rtol=1e-4, atol=1e-4)


def test_decode_batch_rows_independent(setup):
    """Each batch row decodes independently (no cross-row leakage)."""
    params, la, lb, sc = setup
    # two identical rows with different adapters must produce row-wise
    # results equal to their single-row runs
    _, kc1, vc1 = _prefill_one(params, la, lb, sc, [2, 4], 0)
    _, kc2, vc2 = _prefill_one(params, la, lb, sc, [2, 4], 1)
    kc = jnp.concatenate([kc1, kc2], axis=1)
    vc = jnp.concatenate([vc1, vc2], axis=1)
    logits, _, _ = M.decode(params, la, lb, sc, kc, vc,
                            jnp.array([6, 6], jnp.int32),
                            jnp.array([0, 1], jnp.int32),
                            jnp.array([2, 2], jnp.int32), CFG)
    s1, _, _ = M.decode(params, la, lb, sc, kc1, vc1,
                        jnp.array([6], jnp.int32),
                        jnp.array([0], jnp.int32),
                        jnp.array([2], jnp.int32), CFG)
    s2, _, _ = M.decode(params, la, lb, sc, kc2, vc2,
                        jnp.array([6], jnp.int32),
                        jnp.array([1], jnp.int32),
                        jnp.array([2], jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(s1[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(logits[1]), np.asarray(s2[0]),
                               rtol=1e-4, atol=1e-4)


def test_reference_generate_deterministic(setup):
    params, la, lb, sc = setup
    t1 = M.reference_generate(params, la, lb, sc, [1, 2, 3], 0, 5, CFG)
    t2 = M.reference_generate(params, la, lb, sc, [1, 2, 3], 0, 5, CFG)
    assert t1 == t2
    assert len(t1) == 5
    assert all(0 <= t < CFG.vocab for t in t1)


def test_param_names_match_shapes():
    names = M.param_names(CFG)
    shapes = M.param_shapes(CFG)
    assert set(names) == set(shapes)
    assert len(names) == len(set(names))
    # ABI order is stable
    assert names[0] == "embed" and names[-1] == "unembed"


def test_init_params_shapes():
    params = M.init_params(jax.random.PRNGKey(1), CFG)
    for name, shape in M.param_shapes(CFG).items():
        assert params[name].shape == tuple(shape), name
