"""AOT plumbing: arg specs, manifest structure, HLO text emission."""

import json

import jax
import jax.numpy as jnp

from compile import aot, model as M


def test_arg_specs_prefill_abi():
    cfg = M.ModelConfig()
    specs = aot._arg_specs_prefill(cfg, b=2, lp=32)
    names = [n for n, _ in specs]
    n_params = len(M.param_names(cfg))
    assert names[:n_params] == ["param:" + n for n in M.param_names(cfg)]
    assert names[n_params:] == ["lora_a", "lora_b", "scalings", "tokens",
                                "bseg", "lens"]
    spec = dict(specs)
    assert spec["lora_a"].shape == (aot.BATCH_SLOTS, cfg.d_model, cfg.r_max)
    assert spec["tokens"].shape == (2, 32)
    assert spec["bseg"].shape == (2 * 32 // cfg.block_tokens,)


def test_arg_specs_decode_abi():
    cfg = M.ModelConfig()
    specs = aot._arg_specs_decode(cfg, b=4)
    spec = dict(specs)
    assert spec["k_cache"].shape == (cfg.n_layers, 4, cfg.max_seq,
                                     cfg.n_heads, cfg.head_dim)
    assert spec["tokens"].shape == (4,)
    names = [n for n, _ in specs]
    assert names[-3:] == ["tokens", "bseg", "pos"]


def test_to_hlo_text_smoke():
    """The text interchange path itself (stablehlo -> XlaComputation)."""
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text


def test_adapter_bank_deterministic():
    cfg = M.ModelConfig(d_model=16, r_max=128)
    k = jax.random.PRNGKey(aot.SEED)
    b1 = aot.make_adapter_bank(k, cfg)
    b2 = aot.make_adapter_bank(k, cfg)
    assert len(b1) == len(aot.BANK_RANKS)
    for (a1, bb1, al1), (a2, bb2, al2) in zip(b1, b2):
        assert al1 == al2
        assert (a1 == a2).all() and (bb1 == bb2).all()
    # ranks as advertised
    for (a, b, alpha), r in zip(b1, aot.BANK_RANKS):
        assert a.shape == (cfg.d_model, r)
        assert b.shape == (r, cfg.d_model)
        assert alpha == 2 * r


def test_manifest_args_json_serializable():
    cfg = M.ModelConfig()
    specs = aot._arg_specs_decode(cfg, b=1)
    args = aot._manifest_args(specs)
    json.dumps(args)  # must not raise
    assert all(a["dtype"] in ("float32", "int32") for a in args)
