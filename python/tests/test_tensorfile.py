"""tensorfile round-trip + format pinning (the rust loader must agree)."""

import struct

import numpy as np
import pytest

from compile import tensorfile


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.bin")
    tensors = [
        ("a", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("b.scale", np.array([1.5], np.float32)),
        ("idx", np.array([[1, 2], [3, 4]], np.int32)),
        ("scalar0d", np.array(7, np.int32)),
    ]
    tensorfile.write_tensors(path, tensors)
    out = tensorfile.read_tensors(path)
    assert list(out) == ["a", "b.scale", "idx", "scalar0d"]
    for name, arr in tensors:
        np.testing.assert_array_equal(out[name], arr)
        assert out[name].dtype == arr.dtype


def test_header_layout_pinned(tmp_path):
    """Byte-level pin: rust/src/runtime/tensorfile.rs parses this exact
    layout; if this test changes, change the rust side too."""
    path = str(tmp_path / "t.bin")
    tensorfile.write_tensors(path, [("x", np.zeros((2,), np.float32))])
    raw = open(path, "rb").read()
    assert raw[:4] == b"LSTF"
    version, count = struct.unpack_from("<II", raw, 4)
    assert (version, count) == (1, 1)
    name_len = struct.unpack_from("<H", raw, 12)[0]
    assert name_len == 1 and raw[14:15] == b"x"
    dtype, ndim = struct.unpack_from("<BB", raw, 15)
    assert (dtype, ndim) == (0, 1)
    dim0 = struct.unpack_from("<I", raw, 17)[0]
    assert dim0 == 2
    assert len(raw) == 21 + 8  # header + 2 f32


def test_unsupported_dtype_rejected(tmp_path):
    with pytest.raises(ValueError):
        tensorfile.write_tensors(str(tmp_path / "t.bin"),
                                 [("x", np.zeros(2, np.float64))])


def test_bad_magic_rejected(tmp_path):
    path = str(tmp_path / "bad.bin")
    with open(path, "wb") as f:
        f.write(b"NOPE" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        tensorfile.read_tensors(path)
