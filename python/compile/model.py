"""L2: LoRA transformer forward (prefill + decode), built on the L1 kernels.

A small decoder-only transformer (pre-RMSNorm, MHA, GELU MLP) with LoRA
adapters on the q/k/v/o projections, applied through the Pallas
multi-adapter kernel so heterogeneous adapters co-batch exactly the way
the paper's serving systems do (pad-to-max-rank SGMV).

Everything here is build-time only: `aot.py` lowers the two entry points
to HLO text and the rust runtime executes them; Python is never on the
request path.

Entry points (functional, KV cache passed in/out):

  prefill(params..., lora_a, lora_b, scalings, tokens, bseg, lens)
      tokens : [B, Lp] int32 (right-padded prompts)
      bseg   : [B*Lp/BT] int32 adapter index per token block
      lens   : [B] int32 true prompt lengths
      -> (logits [B, V] at the last real token, k_cache, v_cache)

  decode(params..., lora_a, lora_b, scalings, k_cache, v_cache,
         tokens, bseg, pos)
      tokens : [B] int32 (previous emitted token per request)
      bseg   : [B] int32 adapter per request (block_tokens=1)
      pos    : [B] int32 position being generated
      -> (logits [B, V], k_cache, v_cache)

KV cache layout: [n_layers, B, Lmax, n_heads, head_dim] for k and v.

Batch layout contract with rust `server/`: co-batched requests are rows;
each row uses one adapter; rows are padded to Lp; inactive rows carry
adapter 0 and are masked by lens/pos on the rust side.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import sgmv


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Dimensions of the mini LoRA transformer served end-to-end."""

    vocab: int = 512
    d_model: int = 256
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 1024
    max_seq: int = 160          # Lmax: prompt budget + decode budget
    r_max: int = 128            # widest adapter rank servable
    block_tokens: int = 32      # SGMV token-block size for prefill

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def param_names(cfg: ModelConfig) -> List[str]:
    """Deterministic parameter order — the artifact ABI (see manifest)."""
    names = ["embed"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1", f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.wo",
            f"l{i}.ln2", f"l{i}.w1", f"l{i}.w2",
        ]
    names += ["ln_f", "unembed"]
    return names


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, f = cfg.d_model, cfg.d_ff
    shapes: Dict[str, Tuple[int, ...]] = {"embed": (cfg.vocab, d)}
    for i in range(cfg.n_layers):
        shapes[f"l{i}.ln1"] = (d,)
        shapes[f"l{i}.wq"] = (d, d)
        shapes[f"l{i}.wk"] = (d, d)
        shapes[f"l{i}.wv"] = (d, d)
        shapes[f"l{i}.wo"] = (d, d)
        shapes[f"l{i}.ln2"] = (d,)
        shapes[f"l{i}.w1"] = (d, f)
        shapes[f"l{i}.w2"] = (f, d)
    shapes["ln_f"] = (d,)
    shapes["unembed"] = (d, cfg.vocab)
    return shapes


def init_params(key, cfg: ModelConfig) -> Dict[str, jax.Array]:
    """Random init; scale chosen to keep logits O(1) for greedy decoding."""
    params: Dict[str, jax.Array] = {}
    shapes = param_shapes(cfg)
    keys = jax.random.split(key, len(shapes))
    for k, name in zip(keys, param_names(cfg)):
        shape = shapes[name]
        if len(shape) == 1:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = (jax.random.normal(k, shape, jnp.float32)
                            * (1.0 / jnp.sqrt(fan_in)))
    return params


def _rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _lora_proj(x_flat, w, lora_a, lora_b, scalings, bseg, block_tokens,
               interpret=True):
    """Base projection + multi-adapter LoRA delta via the Pallas kernel."""
    base = x_flat @ w
    delta = sgmv.bgmv_padded(x_flat, bseg, lora_a, lora_b, scalings,
                             block_tokens=block_tokens, interpret=interpret)
    return base + delta


def _attention_prefill(q, k, v, lens):
    """Causal self-attention over the padded prompt.

    q,k,v: [B, Lp, H, Dh]. Padding tokens (>= lens) are masked out of the
    key side; their query outputs are garbage but never read (logits are
    gathered at lens-1, and decode overwrites cache rows past lens before
    ever attending to them).
    """
    b, t, h, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    qpos = jnp.arange(t)[None, None, :, None]
    kpos = jnp.arange(t)[None, None, None, :]
    causal = kpos <= qpos
    valid = kpos < lens[:, None, None, None]
    mask = jnp.logical_and(causal, valid)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(b, t, h * dh)


def _attention_decode(q, k_cache_l, v_cache_l, pos):
    """Single-position attention against the cache.

    q: [B, H, Dh]; caches: [B, Lmax, H, Dh]; pos: [B] (index of the query
    token, already written into the cache).
    """
    b, lmax, h, dh = k_cache_l.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    logits = jnp.einsum("bhd,bkhd->bhk", q, k_cache_l) * scale
    kpos = jnp.arange(lmax)[None, None, :]
    mask = kpos <= pos[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, v_cache_l)
    return out.reshape(b, h * dh)


def prefill(params: Dict[str, jax.Array], lora_a, lora_b, scalings,
            tokens, bseg, lens, cfg: ModelConfig, interpret=True):
    b, lp = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    bt = cfg.block_tokens
    x = jnp.take(params["embed"], tokens, axis=0)  # [B, Lp, d]

    k_cache = jnp.zeros((cfg.n_layers, b, cfg.max_seq, h, dh), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)

    for i in range(cfg.n_layers):
        xn = _rms_norm(x, params[f"l{i}.ln1"])
        xf = xn.reshape(b * lp, d)
        q = _lora_proj(xf, params[f"l{i}.wq"], lora_a, lora_b, scalings,
                       bseg, bt, interpret)
        k = _lora_proj(xf, params[f"l{i}.wk"], lora_a, lora_b, scalings,
                       bseg, bt, interpret)
        v = _lora_proj(xf, params[f"l{i}.wv"], lora_a, lora_b, scalings,
                       bseg, bt, interpret)
        q = q.reshape(b, lp, h, dh)
        k = k.reshape(b, lp, h, dh)
        v = v.reshape(b, lp, h, dh)
        k_cache = k_cache.at[i, :, :lp].set(k)
        v_cache = v_cache.at[i, :, :lp].set(v)
        attn = _attention_prefill(q, k, v, lens)  # [B, Lp, d]
        o = _lora_proj(attn.reshape(b * lp, d), params[f"l{i}.wo"],
                       lora_a, lora_b, scalings, bseg, bt, interpret)
        x = x + o.reshape(b, lp, d)
        xn = _rms_norm(x, params[f"l{i}.ln2"])
        hmid = jax.nn.gelu(xn @ params[f"l{i}.w1"])
        x = x + hmid @ params[f"l{i}.w2"]

    x = _rms_norm(x, params["ln_f"])
    # Logits at the last *real* token of each row.
    last = jnp.clip(lens - 1, 0, lp - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    logits = x_last @ params["unembed"]
    return logits, k_cache, v_cache


def decode(params: Dict[str, jax.Array], lora_a, lora_b, scalings,
           k_cache, v_cache, tokens, bseg, pos, cfg: ModelConfig,
           interpret=True):
    b = tokens.shape[0]
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = jnp.take(params["embed"], tokens, axis=0)  # [B, d]

    for i in range(cfg.n_layers):
        xn = _rms_norm(x, params[f"l{i}.ln1"])
        q = _lora_proj(xn, params[f"l{i}.wq"], lora_a, lora_b, scalings,
                       bseg, 1, interpret)
        k = _lora_proj(xn, params[f"l{i}.wk"], lora_a, lora_b, scalings,
                       bseg, 1, interpret)
        v = _lora_proj(xn, params[f"l{i}.wv"], lora_a, lora_b, scalings,
                       bseg, 1, interpret)
        q = q.reshape(b, h, dh)
        k = k.reshape(b, h, dh)
        v = v.reshape(b, h, dh)
        bidx = jnp.arange(b)
        k_cache = k_cache.at[i, bidx, pos].set(k)
        v_cache = v_cache.at[i, bidx, pos].set(v)
        attn = _attention_decode(q, k_cache[i], v_cache[i], pos)
        o = _lora_proj(attn, params[f"l{i}.wo"], lora_a, lora_b, scalings,
                       bseg, 1, interpret)
        x = x + o
        xn = _rms_norm(x, params[f"l{i}.ln2"])
        hmid = jax.nn.gelu(xn @ params[f"l{i}.w1"])
        x = x + hmid @ params[f"l{i}.w2"]

    x = _rms_norm(x, params["ln_f"])
    logits = x @ params["unembed"]
    return logits, k_cache, v_cache


def prefill_flat(cfg: ModelConfig, interpret=True):
    """Entry point over flat positional params — the lowered ABI.

    Argument order: *params (param_names order), lora_a, lora_b,
    scalings, tokens, bseg, lens.
    """
    names = param_names(cfg)

    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        lora_a, lora_b, scalings, tokens, bseg, lens = args[len(names):]
        return prefill(params, lora_a, lora_b, scalings, tokens, bseg,
                       lens, cfg, interpret)

    return fn


def decode_flat(cfg: ModelConfig, interpret=True):
    """Argument order: *params, lora_a, lora_b, scalings, k_cache,
    v_cache, tokens, bseg, pos."""
    names = param_names(cfg)

    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        (lora_a, lora_b, scalings, k_cache, v_cache, tokens, bseg,
         pos) = args[len(names):]
        return decode(params, lora_a, lora_b, scalings, k_cache, v_cache,
                      tokens, bseg, pos, cfg, interpret)

    return fn


def reference_generate(params, lora_a, lora_b, scalings, prompt, adapter_id,
                       n_steps, cfg: ModelConfig):
    """Greedy generation oracle used by tests and by the rust integration
    golden files: prefill one prompt then decode n_steps-1 more tokens."""
    lp = cfg.block_tokens * max(1, (len(prompt) + cfg.block_tokens - 1)
                                // cfg.block_tokens)
    tokens = jnp.zeros((1, lp), jnp.int32).at[0, : len(prompt)].set(
        jnp.array(prompt, jnp.int32))
    bseg = jnp.full((lp // cfg.block_tokens,), adapter_id, jnp.int32)
    lens = jnp.array([len(prompt)], jnp.int32)
    logits, kc, vc = prefill(params, lora_a, lora_b, scalings, tokens,
                             bseg, lens, cfg)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_steps - 1):
        tok = jnp.array([out[-1]], jnp.int32)
        logits, kc, vc = decode(params, lora_a, lora_b, scalings, kc, vc,
                                tok, jnp.array([adapter_id], jnp.int32),
                                jnp.array([pos], jnp.int32), cfg)
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out
