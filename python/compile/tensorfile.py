"""Tiny binary tensor container shared with the rust runtime.

Format (little-endian):
  magic   : 4 bytes b"LSTF"
  version : u32 (=1)
  count   : u32
  per tensor:
    name_len : u16, name utf-8
    dtype    : u8 (0 = f32, 1 = i32)
    ndim     : u8
    dims     : u32 * ndim
    data     : raw little-endian values

Rust counterpart: `rust/src/runtime/tensorfile.rs`. Kept deliberately
dumb — no alignment, no compression — so both sides stay ~100 lines.
"""

from __future__ import annotations

import struct
from typing import Dict, List, Tuple

import numpy as np

MAGIC = b"LSTF"
VERSION = 1
_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
_DTYPES_INV = {0: np.dtype(np.float32), 1: np.dtype(np.int32)}


def write_tensors(path: str, tensors: List[Tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def read_tensors(path: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == VERSION, f"bad version {version}"
        for _ in range(count):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = _DTYPES_INV[dt]
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype)
            out[name] = data.reshape(dims)
    return out
