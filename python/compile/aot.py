"""AOT lowering: jax/pallas model -> HLO text artifacts for the rust runtime.

Emits, into `artifacts/` (gitignored):

  prefill_b{B}_l{Lp}.hlo.txt   prefill executables (one per batch shape)
  decode_b{B}.hlo.txt          decode executables
  params.bin                   base-model weights        (tensorfile)
  adapters.bin                 adapter bank A/B/alpha    (tensorfile)
  manifest.json                ABI: shapes, arg order, model config
  golden.json                  greedy-generation goldens for rust tests

HLO **text** is the interchange format, not `.serialize()`: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 (the version the rust `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Pallas kernels are lowered with interpret=True so they become plain HLO
executable by the CPU PJRT client (real-TPU lowering emits Mosaic
custom-calls the CPU plugin cannot run).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import sgmv
from . import tensorfile

SEED = 0x10AD_5E4E % (2**31)
BATCH_SLOTS = 8  # adapter slots per co-batch (S_b): stacked lora tensor dim

# Adapter bank served end-to-end: ids 0..15, the paper's five rank classes.
BANK_RANKS = [8, 16, 32, 64, 128, 8, 16, 32, 64, 128, 8, 8, 16, 32, 64, 128]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _arg_specs_prefill(cfg: M.ModelConfig, b: int, lp: int):
    shapes = M.param_shapes(cfg)
    names = M.param_names(cfg)
    specs = [("param:" + n, _spec(shapes[n])) for n in names]
    d, r = cfg.d_model, cfg.r_max
    nb = b * lp // cfg.block_tokens
    specs += [
        ("lora_a", _spec((BATCH_SLOTS, d, r))),
        ("lora_b", _spec((BATCH_SLOTS, r, d))),
        ("scalings", _spec((BATCH_SLOTS,))),
        ("tokens", _spec((b, lp), jnp.int32)),
        ("bseg", _spec((nb,), jnp.int32)),
        ("lens", _spec((b,), jnp.int32)),
    ]
    return specs


def _arg_specs_decode(cfg: M.ModelConfig, b: int):
    shapes = M.param_shapes(cfg)
    names = M.param_names(cfg)
    specs = [("param:" + n, _spec(shapes[n])) for n in names]
    d, r, h, dh = cfg.d_model, cfg.r_max, cfg.n_heads, cfg.head_dim
    kv = (cfg.n_layers, b, cfg.max_seq, h, dh)
    specs += [
        ("lora_a", _spec((BATCH_SLOTS, d, r))),
        ("lora_b", _spec((BATCH_SLOTS, r, d))),
        ("scalings", _spec((BATCH_SLOTS,))),
        ("k_cache", _spec(kv)),
        ("v_cache", _spec(kv)),
        ("tokens", _spec((b,), jnp.int32)),
        ("bseg", _spec((b,), jnp.int32)),
        ("pos", _spec((b,), jnp.int32)),
    ]
    return specs


def _manifest_args(specs):
    return [
        {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
        for n, s in specs
    ]


def make_adapter_bank(key, cfg: M.ModelConfig):
    """Deterministic adapter bank: (A, B, alpha) per adapter id."""
    bank = []
    for i, r in enumerate(BANK_RANKS):
        ka, kb = jax.random.split(jax.random.fold_in(key, i))
        a = jax.random.normal(ka, (cfg.d_model, r), jnp.float32) * 0.05
        b = jax.random.normal(kb, (r, cfg.d_model), jnp.float32) * 0.05
        bank.append((a, b, float(2 * r)))
    return bank


def lower_all(cfg: M.ModelConfig, out_dir: str, prefill_shapes,
              decode_batches, fast: bool) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []

    for b, lp in prefill_shapes:
        name = f"prefill_b{b}_l{lp}"
        specs = _arg_specs_prefill(cfg, b, lp)
        fn = M.prefill_flat(cfg)
        lowered = jax.jit(fn).lower(*[s for _, s in specs])
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts.append({
            "name": name, "kind": "prefill", "batch": b, "prompt_len": lp,
            "file": name + ".hlo.txt", "args": _manifest_args(specs),
            "outputs": ["logits", "k_cache", "v_cache"],
        })
        print(f"  lowered {name}: {len(text)} chars")

    for b in decode_batches:
        name = f"decode_b{b}"
        specs = _arg_specs_decode(cfg, b)
        fn = M.decode_flat(cfg)
        lowered = jax.jit(fn).lower(*[s for _, s in specs])
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts.append({
            "name": name, "kind": "decode", "batch": b, "prompt_len": 0,
            "file": name + ".hlo.txt", "args": _manifest_args(specs),
            "outputs": ["logits", "k_cache", "v_cache"],
        })
        print(f"  lowered {name}: {len(text)} chars")

    return {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff, "max_seq": cfg.max_seq, "r_max": cfg.r_max,
            "block_tokens": cfg.block_tokens,
        },
        "batch_slots": BATCH_SLOTS,
        "param_names": M.param_names(cfg),
        "bank_ranks": BANK_RANKS,
        "artifacts": artifacts,
        "seed": SEED,
    }


def emit_goldens(cfg, params, bank, out_dir: str) -> None:
    """Greedy-generation goldens the rust integration tests replay."""
    goldens = []
    cases = [
        # (prompt length, adapter id in bank, steps)
        (5, 0, 6),    # rank 8
        (12, 4, 6),   # rank 128
        (20, 2, 4),   # rank 32
    ]
    for plen, aid, steps in cases:
        rng = np.random.RandomState(plen * 1000 + aid)
        prompt = rng.randint(1, cfg.vocab, size=plen).tolist()
        # Stack a batch with the chosen adapter in slot 0.
        la, lb, sc, _rk = sgmv.stack_adapters([bank[aid]], cfg.d_model,
                                              cfg.r_max)
        pad = BATCH_SLOTS - 1
        la = jnp.concatenate([la, jnp.zeros((pad,) + la.shape[1:])], 0)
        lb = jnp.concatenate([lb, jnp.zeros((pad,) + lb.shape[1:])], 0)
        sc = jnp.concatenate([sc, jnp.zeros((pad,))], 0)
        toks = M.reference_generate(params, la, lb, sc, prompt, 0, steps,
                                    cfg)
        goldens.append({"prompt": prompt, "adapter": aid, "steps": steps,
                        "tokens": toks})
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(goldens, f, indent=1)
    print(f"  goldens: {[g['tokens'] for g in goldens]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="emit the minimal artifact set")
    args = ap.parse_args()
    out_dir = args.out

    cfg = M.ModelConfig()
    # NOTE: for every prefill batch size B there must be a decode
    # artifact with the same B — the KV-cache shapes are baked per batch
    # and the rust engine feeds prefill outputs straight into decode.
    if args.fast:
        prefill_shapes = [(1, 32)]
        decode_batches = [1]
    else:
        prefill_shapes = [(1, 32), (4, 32), (4, 64), (8, 64)]
        decode_batches = [1, 4, 8]

    print("lowering artifacts ...")
    manifest = lower_all(cfg, out_dir, prefill_shapes, decode_batches,
                         args.fast)

    key = jax.random.PRNGKey(SEED)
    params = M.init_params(key, cfg)
    tensorfile.write_tensors(
        os.path.join(out_dir, "params.bin"),
        [(n, np.asarray(params[n])) for n in M.param_names(cfg)],
    )

    bank = make_adapter_bank(jax.random.fold_in(key, 1), cfg)
    bank_tensors = []
    for i, (a, b, alpha) in enumerate(bank):
        bank_tensors.append((f"adapter{i}.a", np.asarray(a)))
        bank_tensors.append((f"adapter{i}.b", np.asarray(b)))
        bank_tensors.append((f"adapter{i}.alpha",
                             np.asarray([alpha], np.float32)))
    tensorfile.write_tensors(os.path.join(out_dir, "adapters.bin"),
                             bank_tensors)

    emit_goldens(cfg, params, bank, out_dir)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts "
          f"to {out_dir}")


if __name__ == "__main__":
    main()
