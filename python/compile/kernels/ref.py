"""Pure-jnp oracles for the multi-adapter LoRA kernels.

These are the correctness references: simple, obviously-right per-token
gather implementations with no tiling or padding tricks. The Pallas
kernels in ``sgmv.py`` are validated against these by
``python/tests/test_kernel.py``.

Shapes (shared by kernels and oracles):
  x       : [T, d]              tokens (co-batched across requests)
  seg_ids : [T] int32           adapter index per token
  lora_a  : [n_adapters, d, r_max]   "shrink" matrices, zero-padded
  lora_b  : [n_adapters, r_max, d]   "expand" matrices, zero-padded
  ranks   : [n_adapters] int32  true rank of each adapter (<= r_max)

The LoRA delta for token t with adapter s = seg_ids[t] is

  delta[t] = (x[t] @ lora_a[s]) @ lora_b[s] * scaling

Rows/columns of A/B beyond the adapter's true rank are zero, so padded
and rank-masked computations agree numerically; what differs between the
kernel variants is the *work* performed, which is the paper's whole point
(pad-to-max-rank interference).
"""

from __future__ import annotations

import jax.numpy as jnp


def lora_delta_ref(x, seg_ids, lora_a, lora_b, scaling=1.0):
    """Per-token gathered LoRA delta: the ground truth.

    Gathers each token's (A, B) pair and applies the two skinny matmuls
    exactly. Same asymptotic work as the real kernels, but with a gather
    of full adapter matrices per token — fine for an oracle.
    """
    a = lora_a[seg_ids]  # [T, d, r_max]
    b = lora_b[seg_ids]  # [T, r_max, d]
    h = jnp.einsum("td,tdr->tr", x, a)
    out = jnp.einsum("tr,trd->td", h, b)
    return out * scaling


def lora_delta_masked_ref(x, seg_ids, lora_a, lora_b, ranks, scaling=1.0):
    """Oracle with explicit rank masking.

    Identical result to ``lora_delta_ref`` when the stacked A/B are
    zero-padded beyond each adapter's rank; used to verify that the
    rank-aware kernel's masking is exact even when the padding of A/B is
    garbage (non-zero).
    """
    a = lora_a[seg_ids]  # [T, d, r_max]
    b = lora_b[seg_ids]  # [T, r_max, d]
    r_max = lora_a.shape[-1]
    mask = jnp.arange(r_max)[None, :] < ranks[seg_ids][:, None]  # [T, r_max]
    h = jnp.einsum("td,tdr->tr", x, a)
    h = jnp.where(mask, h, 0.0)
    out = jnp.einsum("tr,trd->td", h, b)
    return out * scaling


def lora_matmul_ref(x, w, seg_ids, lora_a, lora_b, scaling=1.0):
    """Full LoRA projection: frozen base weight + adapter delta.

      y = x @ w + scaling * (x @ A[seg]) @ B[seg]
    """
    return x @ w + lora_delta_ref(x, seg_ids, lora_a, lora_b, scaling)
