"""Pallas multi-adapter LoRA kernels (L1).

Two variants of the fused heterogeneous-LoRA delta, mirroring the kernels
the paper analyzes (§II-B, §III-A.5):

* ``bgmv_padded`` — Punica-style BGMV: every token block executes GEMMs
  padded to the *maximum* rank present in the stacked adapter tensors,
  regardless of each adapter's true rank. This is the behaviour whose
  interference the paper measures: low-rank requests pay r_max work.

* ``sgmv_rank_aware`` — S-LoRA MBGMV-style segmented gather kernel with
  explicit rank masking. The intermediate activations beyond an adapter's
  true rank are zeroed, so the result is exact even if the stacked A/B
  padding holds garbage. On real hardware the tile shapes (and thus MXU
  occupancy) are still dictated by r_max — the masking trims numerics,
  not the systolic-array schedule — which is exactly the residual
  dependency on the highest rank the paper calls out.

TPU adaptation (see DESIGN.md §3): the CUDA kernels tile per threadblock
and stage adapter slices in shared memory; here the grid iterates over
fixed-size *token blocks* (one adapter per block — the serving engine
lays out co-batched requests contiguously and pads each request to a
block multiple), and the adapter pair for the block is gathered from the
stacked HBM tensors into VMEM-resident tiles. ``interpret=True`` is
mandatory: the CPU PJRT plugin cannot execute Mosaic custom-calls, so the
kernel lowers to plain HLO and the same artifact runs under the rust
runtime.

Batch layout contract (shared with rust `server/` and L2 `model.py`):
  x          : [T, d]   T = n_blocks * block_tokens
  block_seg  : [n_blocks] int32, adapter index of each token block
  lora_a     : [S, d, r_max]   zero-padded shrink matrices
  lora_b     : [S, r_max, d]   zero-padded expand matrices
  scalings   : [S] f32         alpha/rank per adapter
  ranks      : [S] int32       true ranks (rank-aware variant only)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_TOKENS = 8


def _delta_kernel(seg_ref, x_ref, a_ref, b_ref, scale_ref, o_ref, *,
                  rank_aware, ranks_ref=None):
    """One grid step: LoRA delta for a single token block.

    `seg_ref` is the scalar-prefetch operand: the BlockSpec index maps
    below use it to gather *only this block's* adapter pair HBM->VMEM —
    the canonical SGMV schedule (a CUDA kernel does the same staging
    with cp.async into shared memory). The VMEM-resident working set per
    grid step is exactly x-tile + one (A, B) pair + out-tile.
    """
    del seg_ref  # consumed by the index maps
    x = x_ref[...]                # [BT, d]
    a = a_ref[0]                  # [d, r_max]
    b = b_ref[0]                  # [r_max, d]
    scale = scale_ref[0]

    # Shrink: [BT, d] @ [d, r_max]. The MXU tile is r_max wide for every
    # block — this is the pad-to-max-rank cost, present in BOTH variants.
    h = jnp.dot(x, a, preferred_element_type=jnp.float32)  # [BT, r_max]

    if rank_aware:
        r = ranks_ref[0]
        r_max = h.shape[-1]
        mask = jax.lax.broadcasted_iota(jnp.int32, (1, r_max), 1) < r
        h = jnp.where(mask, h, 0.0)

    # Expand: [BT, r_max] @ [r_max, d].
    out = jnp.dot(h, b, preferred_element_type=jnp.float32)  # [BT, d]
    o_ref[...] = (out * scale).astype(o_ref.dtype)


def _lora_delta(x, block_seg, lora_a, lora_b, scalings, ranks, *,
                block_tokens, rank_aware, interpret=True):
    t, d = x.shape
    s_count, d_a, r_max = lora_a.shape
    assert d_a == d, f"lora_a dim {d_a} != x dim {d}"
    assert lora_b.shape == (s_count, r_max, d), lora_b.shape
    assert t % block_tokens == 0, f"T={t} not a multiple of block_tokens={block_tokens}"
    n_blocks = t // block_tokens
    assert block_seg.shape == (n_blocks,), (block_seg.shape, n_blocks)

    if rank_aware:
        def kernel(seg_ref, x_ref, a_ref, b_ref, scale_ref, ranks_ref,
                   o_ref):
            return _delta_kernel(seg_ref, x_ref, a_ref, b_ref, scale_ref,
                                 o_ref, rank_aware=True,
                                 ranks_ref=ranks_ref)
    else:
        def kernel(seg_ref, x_ref, a_ref, b_ref, scale_ref, o_ref):
            return _delta_kernel(seg_ref, x_ref, a_ref, b_ref, scale_ref,
                                 o_ref, rank_aware=False)

    # Scalar-prefetch grid spec: block_seg is available to every index
    # map, so each grid step's BlockSpec gathers one adapter's tensors
    # rather than staging the whole stack (which an earlier version did
    # — see EXPERIMENTS.md §Perf for the before/after).
    in_specs = [
        pl.BlockSpec((block_tokens, d), lambda i, seg: (i, 0)),   # x
        pl.BlockSpec((1, d, r_max), lambda i, seg: (seg[i], 0, 0)),
        pl.BlockSpec((1, r_max, d), lambda i, seg: (seg[i], 0, 0)),
        pl.BlockSpec((1,), lambda i, seg: (seg[i],)),             # scaling
    ]
    args = [block_seg.astype(jnp.int32), x, lora_a, lora_b,
            scalings.astype(jnp.float32)]
    if rank_aware:
        in_specs.append(pl.BlockSpec((1,), lambda i, seg: (seg[i],)))
        args.append(ranks.astype(jnp.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_tokens, d), lambda i, seg: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=interpret,
    )(*args)


def bgmv_padded(x, block_seg, lora_a, lora_b, scalings, *,
                block_tokens=DEFAULT_BLOCK_TOKENS, interpret=True):
    """Punica-style padded BGMV delta: all blocks run r_max-wide GEMMs.

    Correct only when lora_a/lora_b are zero-padded beyond each adapter's
    true rank (the serving engine guarantees this).
    """
    return _lora_delta(x, block_seg, lora_a, lora_b, scalings, None,
                       block_tokens=block_tokens, rank_aware=False,
                       interpret=interpret)


def sgmv_rank_aware(x, block_seg, lora_a, lora_b, scalings, ranks, *,
                    block_tokens=DEFAULT_BLOCK_TOKENS, interpret=True):
    """S-LoRA MBGMV-style delta with exact rank masking.

    Robust to arbitrary values in the padded region of lora_a/lora_b.
    """
    return _lora_delta(x, block_seg, lora_a, lora_b, scalings, ranks,
                       block_tokens=block_tokens, rank_aware=True,
                       interpret=interpret)


def stack_adapters(adapters, d, r_max, dtype=jnp.float32):
    """Stack per-adapter (A [d, r], B [r, d], alpha) into padded tensors.

    Returns (lora_a [S,d,r_max], lora_b [S,r_max,d], scalings [S],
    ranks [S]). Zero-pads beyond each adapter's rank, which makes the
    padded BGMV variant exact.
    """
    s_count = len(adapters)
    lora_a = jnp.zeros((s_count, d, r_max), dtype)
    lora_b = jnp.zeros((s_count, r_max, d), dtype)
    scalings = jnp.zeros((s_count,), jnp.float32)
    ranks = jnp.zeros((s_count,), jnp.int32)
    for i, (a, b, alpha) in enumerate(adapters):
        r = a.shape[1]
        assert a.shape == (d, r) and b.shape == (r, d), (a.shape, b.shape)
        assert r <= r_max, f"rank {r} exceeds r_max {r_max}"
        lora_a = lora_a.at[i, :, :r].set(a.astype(dtype))
        lora_b = lora_b.at[i, :r, :].set(b.astype(dtype))
        scalings = scalings.at[i].set(alpha / r)
        ranks = ranks.at[i].set(r)
    return lora_a, lora_b, scalings, ranks


def expand_block_seg(block_seg, block_tokens):
    """[n_blocks] block-level adapter ids -> [T] per-token seg_ids."""
    return jnp.repeat(block_seg, block_tokens)
