//! Quickstart: load the AOT artifacts, run one real LoRA inference on
//! the PJRT CPU client, and print latencies for two adapter ranks.
//!
//!     make artifacts && cargo run --release --example quickstart

use loraserve::runtime::ModelEngine;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("LORASERVE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    println!("loading engine from {dir}/ ...");
    let t0 = Instant::now();
    let engine = ModelEngine::load(&dir)?;
    let bank = ModelEngine::load_bank(&dir)?;
    println!(
        "engine ready on {} in {:.1}s ({} artifacts, {} bank adapters)",
        engine.platform(),
        t0.elapsed().as_secs_f64(),
        engine.prefill_shapes().len() + engine.decode_batches().len(),
        bank.len(),
    );

    let prompt: Vec<i32> = (1..=24).collect();
    for (label, idx) in [("rank-8 adapter", 0usize), ("rank-128 adapter", 4)]
    {
        let adapter = &bank[idx];
        let t = Instant::now();
        let tokens = engine.generate(&prompt, adapter, 16)?;
        let dt = t.elapsed().as_secs_f64();
        println!(
            "{label} (rank {:3}): {:2} tokens in {:.3}s ({:.1} tok/s) -> {:?}",
            adapter.rank,
            tokens.len(),
            dt,
            tokens.len() as f64 / dt,
            &tokens[..8.min(tokens.len())],
        );
    }
    println!("quickstart OK");
    Ok(())
}
