//! Engine micro-timing: prefill and decode step latencies on the real
//! PJRT path — the L1/L2 hot-path measurements for EXPERIMENTS §Perf.
//!
//!     cargo run --release --example engine_bench

use loraserve::runtime::ModelEngine;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("LORASERVE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let engine = ModelEngine::load(&dir)?;
    let bank = ModelEngine::load_bank(&dir)?;

    for &(b, lp) in &[(1usize, 32usize), (4, 64), (8, 64)] {
        if !engine.prefill_shapes().contains(&(b, lp)) {
            continue;
        }
        // batch of b prompts, mixed adapters (slots 0..b)
        let slots: Vec<usize> = (0..b).map(|i| i % 8).collect();
        let adapters: Vec<Option<&_>> =
            (0..b.min(8)).map(|i| Some(&bank[i])).collect();
        let stack = engine.stack_adapters(&adapters)?;
        let prompts: Vec<Vec<i32>> =
            (0..b).map(|i| (1..24 + i as i32).collect()).collect();

        // prefill timing
        let t0 = Instant::now();
        let n_pf = 10;
        let mut kv = None;
        for _ in 0..n_pf {
            let (_, k) = engine.prefill((b, lp), &prompts, &slots, &stack)?;
            kv = Some(k);
        }
        let pf = t0.elapsed().as_secs_f64() / n_pf as f64;

        // decode timing
        let mut kv = kv.unwrap();
        let tokens = vec![5i32; b];
        let mut pos: Vec<i32> = (0..b).map(|_| 30).collect();
        let mut slots_row = slots.clone();
        slots_row.resize(b, 0);
        let n_dec = 30;
        let t0 = Instant::now();
        for _ in 0..n_dec {
            let (_, nkv) =
                engine.decode(kv, &tokens, &slots_row, &pos, &stack)?;
            kv = nkv;
            for p in pos.iter_mut() {
                *p += 1;
            }
        }
        let dec = t0.elapsed().as_secs_f64() / n_dec as f64;
        println!(
            "b={b} lp={lp}: prefill {:.1} ms ({:.0} tok/s), decode step \
             {:.1} ms ({:.0} tok/s)",
            pf * 1e3,
            (b * lp) as f64 / pf,
            dec * 1e3,
            b as f64 / dec,
        );
    }
    Ok(())
}
