//! Placement explorer: watch Algorithm 1 react to workload drift.
//!
//! Simulates a shifting-skew workload window by window, printing the
//! demand the coordinator projects, the rank budgets, the resulting
//! placement (which ranks each server hosts, expected utilization),
//! and the migration traffic against the previous window — the
//! dynamics of Fig 13/16 in one terminal view.
//!
//!     cargo run --release --example placement_explorer [--windows N]

use loraserve::config::ServerConfig;
use loraserve::coordinator::DemandTracker;
use loraserve::placement::loraserve::LoraServePlacer;
use loraserve::placement::{Assignment, PlacementCtx, Placer};
use loraserve::sim::profile::empirical_operating_points;
use loraserve::trace::azure::{AzureConfig, RankPopularity};
use loraserve::trace::azure;
use loraserve::util::cli::Args;
use loraserve::util::table::fmt_bytes;
use loraserve::workload::RANK_CLASSES;

fn main() -> Result<(), String> {
    let args = Args::from_env(&[])?;
    let n_windows = args.get_usize("windows", 6)?;
    let n_servers = args.get_usize("servers", 4)?;
    let window = 200.0; // seconds per placement window

    let trace = azure::generate(&AzureConfig {
        popularity: RankPopularity::ShiftingSkew,
        rps: 20.0,
        duration: window * n_windows as f64,
        seed: args.get_u64("seed", 0)?,
        ..Default::default()
    });
    println!(
        "trace: {} requests over {:.0}s, {} adapters, shifting skew\n",
        trace.requests.len(),
        trace.duration(),
        trace.adapters.len()
    );

    let server = ServerConfig::default();
    let oppoints =
        empirical_operating_points(&server, &RANK_CLASSES, 10.0);
    println!("profiled operating points (tokens/s under SLO):");
    for (r, op) in &oppoints {
        println!("  rank {r:3}: {op:6.0}");
    }

    let mut tracker = DemandTracker::new(window, 16);
    let mut placer = LoraServePlacer::new();
    let mut prev: Option<Assignment> = None;
    let mut req_iter = trace.requests.iter().peekable();

    for w in 0..n_windows {
        let t_end = (w + 1) as f64 * window;
        while let Some(r) = req_iter.peek() {
            if r.arrival > t_end {
                break;
            }
            let r = req_iter.next().unwrap();
            tracker.record(r.adapter, r.total_tokens());
        }
        tracker.roll_window();
        let projected = tracker.projected_tps();
        let ctx = PlacementCtx {
            adapters: &trace.adapters,
            n_servers,
            demand_tps: &projected,
            operating_points: &oppoints,
            prev: prev.as_ref(),
        };
        let asg = placer.place(&ctx);
        asg.validate(n_servers).map_err(|e| e.to_string())?;

        println!("\n== window {w} (t <= {t_end:.0}s)");
        // rank-level demand
        let mut by_rank = std::collections::BTreeMap::new();
        for (a, tps) in &projected {
            let rank = trace.adapters.get(*a).rank;
            *by_rank.entry(rank).or_insert(0.0) += tps;
        }
        print!("   projected demand: ");
        for (r, tps) in &by_rank {
            print!("r{r}:{tps:.0}tps ");
        }
        println!();
        let utils = asg.server_utils(
            n_servers,
            &trace.adapters,
            &projected,
            &oppoints,
        );
        for s in 0..n_servers {
            let mut ranks: Vec<u32> = asg
                .adapters_on(s)
                .iter()
                .map(|&a| trace.adapters.get(a).rank)
                .collect();
            ranks.sort_unstable();
            ranks.dedup();
            println!(
                "   server {s}: util {:.2}, {} adapters, ranks {:?}",
                utils[s],
                asg.adapters_on(s).len(),
                ranks
            );
        }
        if let Some(p) = &prev {
            println!(
                "   migration: {}",
                fmt_bytes(asg.migration_bytes(p, &trace.adapters))
            );
        }
        prev = Some(asg);
    }
    println!("\nplacement_explorer OK");
    Ok(())
}
