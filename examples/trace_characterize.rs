//! Trace characterization walk-through: synthesize the production-like
//! trace and print the §III-B statistics (rank shares, top-k
//! concentration, per-adapter arrival drift) — the workload analysis
//! that motivates dynamic placement.
//!
//!     cargo run --release --example trace_characterize [--adapters N]

use loraserve::trace::characterize;
use loraserve::trace::production::{self, ProductionConfig};
use loraserve::util::cli::Args;
use loraserve::util::stats::moving_average;

fn main() -> Result<(), String> {
    let args = Args::from_env(&[])?;
    let n_adapters = args.get_usize("adapters", 100)?;
    let trace = production::generate(&ProductionConfig {
        n_adapters,
        n_requests: 50_000,
        duration: 3600.0,
        seed: args.get_u64("seed", 0)?,
        ..Default::default()
    });
    println!(
        "trace '{}': {} requests / {:.0}s / {} adapters\n",
        trace.name,
        trace.requests.len(),
        trace.duration(),
        trace.adapters.len()
    );

    println!("rank-wise shares (Fig 15):");
    let req = characterize::rank_request_shares(&trace);
    let tok = characterize::rank_token_shares(&trace);
    for ((r, rs), (_, ts)) in req.iter().zip(tok.iter()) {
        println!("  rank {r:3}: {:5.1}% requests, {:5.1}% tokens", rs * 100.0, ts * 100.0);
    }

    println!("\nadapter concentration (Fig 8):");
    for k in [1usize, 5, 10, 20] {
        println!(
            "  top-{k:2}: {:5.1}% of requests",
            characterize::top_k_request_share(&trace, k) * 100.0
        );
    }

    println!("\narrival drift of the 3 busiest adapters (Fig 10, rpm):");
    let shares = characterize::adapter_request_shares(&trace);
    for &(a, share) in shares.iter().take(3) {
        let rpm = characterize::requests_per_minute(&trace, a, 1);
        let ma = moving_average(&rpm, 10);
        let probe: Vec<String> = (0..6)
            .map(|i| format!("{:.0}", ma[i * ma.len() / 6]))
            .collect();
        println!(
            "  adapter {a:3} ({:4.1}% share): rpm over time {}",
            share * 100.0,
            probe.join(" -> ")
        );
    }

    println!("\ntrace_characterize OK");
    Ok(())
}
