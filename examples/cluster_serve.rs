//! End-to-end driver (the repo's headline example): a *real*
//! mini-cluster of PJRT-backed LLM servers serving a drifting
//! multi-adapter workload, with LORASERVE placement/routing compared to
//! the S-LoRA Random baseline. All three layers execute for every
//! request: the rust coordinator routes, the server thread runs the
//! AOT-lowered jax model, and the model's q/k/v/o projections go
//! through the Pallas multi-adapter kernel.
//!
//!     make artifacts && cargo run --release --example cluster_serve
//!
//! Flags: --servers N (default 2), --requests N (default 120),
//!        --duration SECS (default 15), --seed S

use loraserve::server::cluster::{
    RealCluster, RealClusterConfig, TimedRequest,
};
use loraserve::sim::SystemKind;
use loraserve::util::cli::Args;
use loraserve::util::rng::Pcg32;
use loraserve::util::table::{fmt_bytes, fmt_secs, Table};

/// Drifting workload over the bank: early traffic concentrates on
/// high-rank adapters, late traffic on low ranks (a miniature of the
/// paper's shifting-skew trace, Fig 16) — the regime where dynamic
/// placement matters.
fn build_workload(
    n: usize,
    duration: f64,
    bank_ranks: &[u32],
    seed: u64,
) -> Vec<TimedRequest> {
    let mut rng = Pcg32::with_stream(seed, 0xe2e);
    let hi: Vec<usize> = bank_ranks
        .iter()
        .enumerate()
        .filter(|(_, &r)| r >= 64)
        .map(|(i, _)| i)
        .collect();
    let lo: Vec<usize> = bank_ranks
        .iter()
        .enumerate()
        .filter(|(_, &r)| r < 64)
        .map(|(i, _)| i)
        .collect();
    (0..n)
        .map(|i| {
            let at = duration * i as f64 / n as f64;
            let f = i as f64 / n as f64;
            let p_hi = 0.7 * (1.0 - f) + 0.1 * f;
            let pool = if rng.f64() < p_hi { &hi } else { &lo };
            let adapter =
                pool[rng.below(pool.len() as u64) as usize] as u32;
            let plen = 8 + rng.below(24) as usize;
            let prompt: Vec<i32> =
                (0..plen).map(|_| 1 + rng.below(500) as i32).collect();
            TimedRequest {
                at,
                adapter,
                prompt,
                output_len: 4 + rng.below(8) as usize,
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]).map_err(anyhow::Error::msg)?;
    let n_servers = args.get_usize("servers", 2).map_err(anyhow::Error::msg)?;
    let n_requests = args.get_usize("requests", 120).map_err(anyhow::Error::msg)?;
    let duration = args.get_f64("duration", 15.0).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 0).map_err(anyhow::Error::msg)?;
    let dir = std::env::var("LORASERVE_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());

    let mut table = Table::new(
        "E2E: real mini-cluster, drifting multi-rank workload",
        &[
            "system", "completed", "throughput", "ttft p50", "ttft p95",
            "tbt p50", "fetches", "fetch bytes", "max resident",
        ],
    );

    for system in [SystemKind::LoraServe, SystemKind::SLoraRandom] {
        println!(
            "== starting {} cluster ({n_servers} servers; engines compiling...)",
            system.label()
        );
        let mut cluster = RealCluster::start(RealClusterConfig {
            n_servers,
            artifacts_dir: dir.clone(),
            system,
            rebalance_period: duration / 4.0,
            seed,
        })?;
        let ranks: Vec<u32> =
            cluster.adapters.iter().map(|a| a.rank).collect();
        let workload =
            build_workload(n_requests, duration, &ranks, seed);
        let mut report = cluster.run(&workload)?;
        cluster.shutdown();
        println!(
            "== {}: {} completed in {:.1}s",
            report.system, report.completed, report.wall_secs
        );
        table.row(vec![
            report.system.clone(),
            report.completed.to_string(),
            format!("{:.2} req/s", report.throughput_rps()),
            fmt_secs(report.ttft.p50()),
            fmt_secs(report.ttft.p95()),
            fmt_secs(report.tbt.p50()),
            report.fetches.to_string(),
            fmt_bytes(report.fetch_bytes),
            report
                .per_server_resident
                .iter()
                .max()
                .copied()
                .unwrap_or(0)
                .to_string(),
        ]);
    }
    table.emit("results", "e2e_cluster_serve")?;
    println!("cluster_serve OK");
    Ok(())
}
